//! The execution-plan IR and the pass pipeline that lowers a frozen
//! topology into it.
//!
//! `build_plan` consumes a backend-neutral view of a recorded program
//! (`PlanInput`, with scalar `Op` / batched `BOp` streams unified into
//! [`GOp`]) and runs, in order:
//!
//! 1. **liveness** — reverse reachability from the output node; nodes
//!    that never feed the potential are dead and emit nothing;
//! 2. **constant folding** — live nodes that do not depend on an input,
//!    a rebindable slot leaf, or a composite kernel are *constified*:
//!    they keep their recorded value in a pinned register and are never
//!    recomputed (no arithmetic is re-associated — the recorded value
//!    is exactly what every interpreted forward would recompute, so
//!    folding is bitwise-neutral by construction);
//! 3. **superblock fusion** — maximal runs of elementwise ops between
//!    composite kernels collapse into a single [`FwdInstr::Run`] over a
//!    contiguous [`MicroOp`] span, dispatched once per run.  Ops inside
//!    a run execute in recorded order, so IEEE evaluation order is
//!    untouched;
//! 4. **linear-scan slot reuse** — node values and adjoints are
//!    re-slotted into a small recycled register file.  Values read by
//!    the backward sweep, inputs, the output, constants and rebindable
//!    slot leaves are *pinned* (never recycled); everything else is
//!    freed at its last forward use.  Adjoint slots are recycled during
//!    the descending backward emission.  The remap tables
//!    (`input_val_slots`, `slot_node_slots`, `parents`) are how
//!    data-slot rebinding and the debug replay audit survive
//!    re-slotting.
//!
//! The backward stream replicates the interpreter's reverse sweep on
//! the live, gradient-relevant subgraph: one instruction per node,
//! edges to gradient-irrelevant parents pruned (their adjoints can
//! never reach an input adjoint), each adjoint register zeroed exactly
//! once before its first accumulation, and the zero-adjoint skip
//! preserved per instruction.  Composite edges keep their recorded
//! `j`-order.  The result is bitwise-identical input adjoints — pinned
//! by `rust/tests/tape_opt.rs` against the interpreter on random
//! programs and the whole model zoo.

use crate::autodiff::{CompKind, DataSlot};

/// Sentinel for "no slot": a pruned adjoint edge or an unused operand.
pub(super) const NONE: u32 = u32::MAX;

/// Backend-neutral op: the union of the scalar tape's `Op` and the
/// batched tape's `BOp`.  Scalar composites map to `Composite` with
/// `pstart == xstart == start`; `Tanh` only occurs in scalar programs
/// and `CompositeShared` only in batched ones.
#[derive(Debug, Clone, Copy)]
pub(super) enum GOp {
    Leaf,
    Input,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Tanh(u32),
    Powi(u32, i32),
    Scale(u32, f64),
    Offset(u32, f64),
    Composite { pstart: u32, xstart: u32, len: u32 },
    CompositeShared { pstart: u32, sstart: u32, len: u32 },
}

impl GOp {
    /// Visit every parent node id (composites via the shared arena).
    pub(super) fn for_each_parent(&self, arena: &[u32], mut f: impl FnMut(u32)) {
        match *self {
            GOp::Leaf | GOp::Input => {}
            GOp::Add(x, y) | GOp::Sub(x, y) | GOp::Mul(x, y) | GOp::Div(x, y) => {
                f(x);
                f(y);
            }
            GOp::Neg(x)
            | GOp::Exp(x)
            | GOp::Ln(x)
            | GOp::Log1p(x)
            | GOp::Sqrt(x)
            | GOp::Sigmoid(x)
            | GOp::Softplus(x)
            | GOp::Tanh(x)
            | GOp::Powi(x, _)
            | GOp::Scale(x, _)
            | GOp::Offset(x, _) => f(x),
            GOp::Composite { pstart, len, .. } | GOp::CompositeShared { pstart, len, .. } => {
                for j in 0..len as usize {
                    f(arena[pstart as usize + j]);
                }
            }
        }
    }

    pub(super) fn is_composite(&self) -> bool {
        matches!(self, GOp::Composite { .. } | GOp::CompositeShared { .. })
    }

    fn has_instr(&self) -> bool {
        !matches!(self, GOp::Leaf | GOp::Input)
    }
}

/// One fused elementwise operation inside a [`FwdInstr::Run`].  All
/// operands are *register slots*, not node ids.
#[derive(Debug, Clone, Copy)]
pub(super) enum MicroOp {
    Add { x: u32, y: u32, d: u32 },
    Sub { x: u32, y: u32, d: u32 },
    Mul { x: u32, y: u32, d: u32 },
    Div { x: u32, y: u32, d: u32 },
    Neg { x: u32, d: u32 },
    Exp { x: u32, d: u32 },
    Ln { x: u32, d: u32 },
    Log1p { x: u32, d: u32 },
    Sqrt { x: u32, d: u32 },
    Sigmoid { x: u32, d: u32 },
    Softplus { x: u32, d: u32 },
    Tanh { x: u32, d: u32 },
    Powi { x: u32, d: u32, n: i32 },
    Scale { x: u32, d: u32, c: f64 },
    Offset { x: u32, d: u32, c: f64 },
}

/// Forward-plan instruction: a fused elementwise superblock or one
/// composite kernel call.  Composite operands keep their recorded
/// arena indices — the parent span is remapped to register slots
/// through [`ExecPlan::parents`], while partial/const indices are
/// untouched (those arenas are not re-slotted, which is what keeps
/// `Coeffs`/`Consts` data-slot rebinding working unchanged).
#[derive(Debug, Clone, Copy)]
pub(super) enum FwdInstr {
    /// Execute `micro[start .. start + len]` in order.
    Run { start: u32, len: u32 },
    Composite { dst: u32, kind: CompKind, pstart: u32, xstart: u32, len: u32 },
    CompositeShared { dst: u32, pstart: u32, sstart: u32, len: u32 },
}

/// Backward-plan instruction.  `a` is the node's own adjoint register;
/// `ax`/`ay` are parent adjoint registers (`NONE` when the edge was
/// pruned as gradient-irrelevant); `v*` are the pinned value registers
/// the interpreter's reverse rule reads (`NONE` when the surviving
/// edges do not need them).  Composite edges live in
/// `ExecPlan::{edge_adj, edge_partial}[estart .. estart + elen]`.
#[derive(Debug, Clone, Copy)]
pub(super) enum BwdInstr {
    /// `adj[a] = 0` — emitted exactly once per adjoint register, before
    /// its first accumulation (the re-slotted equivalent of the
    /// interpreter's upfront memset).
    Zero { a: u32 },
    /// `adj[a] = 1` — the output seed; emitted after the input zeros so
    /// an output-is-input program seeds correctly.
    Seed { a: u32 },
    Add { a: u32, ax: u32, ay: u32 },
    Sub { a: u32, ax: u32, ay: u32 },
    Mul { a: u32, ax: u32, ay: u32, vx: u32, vy: u32 },
    Div { a: u32, ax: u32, ay: u32, vx: u32, vy: u32 },
    Neg { a: u32, ax: u32 },
    Exp { a: u32, ax: u32, v: u32 },
    Sqrt { a: u32, ax: u32, v: u32 },
    Sigmoid { a: u32, ax: u32, v: u32 },
    Tanh { a: u32, ax: u32, v: u32 },
    Ln { a: u32, ax: u32, vx: u32 },
    Log1p { a: u32, ax: u32, vx: u32 },
    Softplus { a: u32, ax: u32, vx: u32 },
    Powi { a: u32, ax: u32, vx: u32, n: i32 },
    Scale { a: u32, ax: u32, c: f64 },
    Offset { a: u32, ax: u32 },
    /// Per-lane partials at `edge_partial[e]` (absolute scalar arena
    /// index; the batched executor scales by `lanes`).
    Composite { a: u32, estart: u32, elen: u32 },
    /// Lane-shared coefficients at `edge_partial[e]` into the shared
    /// arena.
    CompositeShared { a: u32, estart: u32, elen: u32 },
}

/// Plan statistics, surfaced through
/// `CompiledModel::plan_stats` / the `tape_opt` bench section.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanStats {
    /// Recorded nodes in the frozen topology.
    pub nodes_total: usize,
    /// Nodes reachable from the output (survive DCE).
    pub nodes_live: usize,
    /// Live nodes constant-folded into pinned registers.
    pub nodes_folded: usize,
    /// Fused elementwise superblocks in the forward plan.
    pub fused_runs: usize,
    /// Total elementwise micro-ops across all runs.
    pub micro_ops: usize,
    /// Composite kernel calls in the forward plan.
    pub composites: usize,
    /// Forward-plan instructions (runs + composites).
    pub fwd_instrs: usize,
    /// Backward-plan instructions (including zero/seed).
    pub bwd_instrs: usize,
    /// Peak live value registers (vs `nodes_total` rows interpreted).
    pub peak_val_slots: usize,
    /// Peak live adjoint registers.
    pub peak_adj_slots: usize,
}

/// A compiled execution plan: the output of the pass pipeline, executed
/// by `opt::dispatch` on a recycled register file.
#[derive(Debug, Clone)]
pub(super) struct ExecPlan {
    pub(super) fwd: Vec<FwdInstr>,
    pub(super) micro: Vec<MicroOp>,
    pub(super) bwd: Vec<BwdInstr>,
    /// Composite backward edges: parent adjoint registers, `j`-ordered.
    pub(super) edge_adj: Vec<u32>,
    /// Composite backward edges: partial / shared-coefficient indices.
    pub(super) edge_partial: Vec<u32>,
    /// The composite parent arena remapped node-id → value register
    /// (`NONE` outside live composite spans).
    pub(super) parents: Vec<u32>,
    /// `(register, recorded value)` pairs materialized at program
    /// construction: folded constants and rebindable slot leaves.
    pub(super) init_values: Vec<(u32, f64)>,
    /// Value register per input, in record order.
    pub(super) input_val_slots: Vec<u32>,
    /// Adjoint register per input, in record order.
    pub(super) input_adj_slots: Vec<u32>,
    pub(super) output_val_slot: u32,
    /// Rebindable spans, copied verbatim (`Coeffs`/`Consts` indices are
    /// not re-slotted; `Nodes` slots resolve via `slot_node_slots`).
    pub(super) data_slots: Vec<DataSlot>,
    /// Value register per `slot_nodes` entry — the slot-remap table
    /// that keeps `SlotStore::Nodes` rebinding working after re-slotting.
    pub(super) slot_node_slots: Vec<u32>,
    pub(super) num_val_slots: usize,
    pub(super) num_adj_slots: usize,
    pub(super) stats: PlanStats,
}

/// Borrowed view of a frozen topology, backend-neutral.  `rec_values`
/// are the recorded node values (lane 0 for batched programs — leaves
/// are lane-uniform by construction) used to materialize folded
/// constants and slot-leaf initial data.
pub(super) struct PlanInput<'a> {
    pub(super) ops: &'a [GOp],
    pub(super) comp_kinds: &'a [CompKind],
    pub(super) arena_parents: &'a [u32],
    pub(super) inputs: &'a [u32],
    pub(super) data_slots: &'a [DataSlot],
    pub(super) slot_nodes: &'a [u32],
    pub(super) output: u32,
    pub(super) rec_values: &'a [f64],
}

fn ensure_adj(
    p: usize,
    grad_rel: &[bool],
    adj_slot: &mut [u32],
    free_adj: &mut Vec<u32>,
    next_adj: &mut u32,
    bwd: &mut Vec<BwdInstr>,
) -> u32 {
    if !grad_rel[p] {
        return NONE;
    }
    if adj_slot[p] == NONE {
        let s = free_adj.pop().unwrap_or_else(|| {
            let s = *next_adj;
            *next_adj += 1;
            s
        });
        adj_slot[p] = s;
        bwd.push(BwdInstr::Zero { a: s });
    }
    adj_slot[p]
}

/// Run the pass pipeline over a frozen topology.
pub(super) fn build_plan(inp: &PlanInput) -> ExecPlan {
    let n = inp.ops.len();
    let out = inp.output as usize;
    assert!(out < n, "build_plan: output node out of range");

    let mut is_input = vec![false; n];
    for &id in inp.inputs {
        is_input[id as usize] = true;
    }
    let mut is_slot_node = vec![false; n];
    for &id in inp.slot_nodes {
        is_slot_node[id as usize] = true;
    }

    // -- pass 1: liveness (reverse reachability from the output) ---------
    let mut live = vec![false; n];
    live[out] = true;
    for i in (0..n).rev() {
        if live[i] {
            inp.ops[i].for_each_parent(inp.arena_parents, |p| live[p as usize] = true);
        }
    }

    // -- pass 2: varying / gradient-relevance classification -------------
    // A node varies across replays if it is an input, a rebindable slot
    // leaf, a composite (its partial/const arenas can be rebound), or
    // has a varying parent.  Live non-varying nodes are folded: their
    // recorded value is exactly what every interpreted forward would
    // recompute, so pinning it is bitwise-neutral.
    let mut varying = vec![false; n];
    let mut grad_rel = vec![false; n];
    for i in 0..n {
        let mut v = is_input[i] || is_slot_node[i] || inp.ops[i].is_composite();
        let mut g = is_input[i];
        inp.ops[i].for_each_parent(inp.arena_parents, |p| {
            v |= varying[p as usize];
            g |= grad_rel[p as usize];
        });
        varying[i] = v;
        grad_rel[i] = g;
    }

    let recompute: Vec<bool> = (0..n)
        .map(|i| live[i] && varying[i] && inp.ops[i].has_instr())
        .collect();
    let constify: Vec<bool> = (0..n).map(|i| live[i] && !varying[i]).collect();
    let bwd_emit: Vec<bool> = (0..n)
        .map(|i| live[i] && grad_rel[i] && inp.ops[i].has_instr())
        .collect();

    // -- pass 3a: pin values the backward sweep reads ---------------------
    let mut val_pin = vec![false; n];
    for i in 0..n {
        if !bwd_emit[i] {
            continue;
        }
        match inp.ops[i] {
            GOp::Mul(x, y) => {
                if grad_rel[x as usize] {
                    val_pin[y as usize] = true;
                }
                if grad_rel[y as usize] {
                    val_pin[x as usize] = true;
                }
            }
            GOp::Div(x, y) => {
                // x-edge reads vy; y-edge reads vx and vy
                if grad_rel[x as usize] || grad_rel[y as usize] {
                    val_pin[y as usize] = true;
                }
                if grad_rel[y as usize] {
                    val_pin[x as usize] = true;
                }
            }
            GOp::Exp(_) | GOp::Sqrt(_) | GOp::Sigmoid(_) | GOp::Tanh(_) => val_pin[i] = true,
            GOp::Ln(x) | GOp::Log1p(x) | GOp::Softplus(x) | GOp::Powi(x, _) => {
                val_pin[x as usize] = true
            }
            _ => {}
        }
    }

    // -- pass 3b: pinned value registers ----------------------------------
    // Inputs (in record order), rebindable slot leaves (even dead ones:
    // they stay valid rebind targets), the output, folded constants and
    // backward-read values get dedicated registers that are never
    // recycled.
    let mut val_slot = vec![NONE; n];
    let mut next_val: u32 = 0;
    for &id in inp.inputs {
        let i = id as usize;
        if val_slot[i] == NONE {
            val_slot[i] = next_val;
            next_val += 1;
        }
    }
    for i in 0..n {
        if (is_slot_node[i] || i == out || constify[i] || val_pin[i]) && val_slot[i] == NONE {
            val_slot[i] = next_val;
            next_val += 1;
        }
    }
    let pinned: Vec<bool> = val_slot.iter().map(|&s| s != NONE).collect();

    // -- pass 3c: last forward use per node (transient lifetimes) ---------
    let mut last_use = vec![usize::MAX; n];
    for i in 0..n {
        if recompute[i] {
            inp.ops[i].for_each_parent(inp.arena_parents, |p| last_use[p as usize] = i);
        }
    }

    // -- pass 4: forward emission (fusion + linear-scan value reuse) ------
    let mut fwd: Vec<FwdInstr> = Vec::new();
    let mut micro: Vec<MicroOp> = Vec::new();
    let mut parents_map: Vec<u32> = vec![NONE; inp.arena_parents.len()];
    let mut free_val: Vec<u32> = Vec::new();
    let mut freed = vec![false; n];
    let mut run_start = 0usize;
    let mut ci = 0usize;

    for i in 0..n {
        let op = inp.ops[i];
        let is_comp = op.is_composite();
        let kind = if is_comp {
            // the kernel-descriptor cursor advances for every composite,
            // live or dead, to stay aligned with the recorded stream
            let k = inp.comp_kinds[ci];
            ci += 1;
            Some(k)
        } else {
            None
        };
        if !recompute[i] {
            continue;
        }
        // free transient parent registers that die here, *before*
        // allocating the destination: the destination may reuse a
        // parent's register (reads precede writes elementwise, and
        // composite kernels finish reading before the result is stored)
        op.for_each_parent(inp.arena_parents, |p| {
            let p = p as usize;
            if !pinned[p] && !freed[p] && last_use[p] == i && val_slot[p] != NONE {
                freed[p] = true;
                free_val.push(val_slot[p]);
            }
        });
        let dst = if val_slot[i] != NONE {
            val_slot[i]
        } else if let Some(s) = free_val.pop() {
            val_slot[i] = s;
            s
        } else {
            let s = next_val;
            next_val += 1;
            val_slot[i] = s;
            s
        };
        if is_comp {
            // close the open elementwise superblock
            if micro.len() > run_start {
                fwd.push(FwdInstr::Run {
                    start: run_start as u32,
                    len: (micro.len() - run_start) as u32,
                });
            }
            match op {
                GOp::Composite { pstart, xstart, len } => {
                    for j in 0..len as usize {
                        let p = inp.arena_parents[pstart as usize + j] as usize;
                        parents_map[pstart as usize + j] = val_slot[p];
                    }
                    fwd.push(FwdInstr::Composite {
                        dst,
                        kind: kind.expect("composite without kernel descriptor"),
                        pstart,
                        xstart,
                        len,
                    });
                }
                GOp::CompositeShared { pstart, sstart, len } => {
                    for j in 0..len as usize {
                        let p = inp.arena_parents[pstart as usize + j] as usize;
                        parents_map[pstart as usize + j] = val_slot[p];
                    }
                    fwd.push(FwdInstr::CompositeShared { dst, pstart, sstart, len });
                }
                _ => unreachable!(),
            }
            run_start = micro.len();
        } else {
            let s = |p: u32| {
                debug_assert!(val_slot[p as usize] != NONE, "parent of a live node unslotted");
                val_slot[p as usize]
            };
            micro.push(match op {
                GOp::Add(x, y) => MicroOp::Add { x: s(x), y: s(y), d: dst },
                GOp::Sub(x, y) => MicroOp::Sub { x: s(x), y: s(y), d: dst },
                GOp::Mul(x, y) => MicroOp::Mul { x: s(x), y: s(y), d: dst },
                GOp::Div(x, y) => MicroOp::Div { x: s(x), y: s(y), d: dst },
                GOp::Neg(x) => MicroOp::Neg { x: s(x), d: dst },
                GOp::Exp(x) => MicroOp::Exp { x: s(x), d: dst },
                GOp::Ln(x) => MicroOp::Ln { x: s(x), d: dst },
                GOp::Log1p(x) => MicroOp::Log1p { x: s(x), d: dst },
                GOp::Sqrt(x) => MicroOp::Sqrt { x: s(x), d: dst },
                GOp::Sigmoid(x) => MicroOp::Sigmoid { x: s(x), d: dst },
                GOp::Softplus(x) => MicroOp::Softplus { x: s(x), d: dst },
                GOp::Tanh(x) => MicroOp::Tanh { x: s(x), d: dst },
                GOp::Powi(x, p) => MicroOp::Powi { x: s(x), d: dst, n: p },
                GOp::Scale(x, c) => MicroOp::Scale { x: s(x), d: dst, c },
                GOp::Offset(x, c) => MicroOp::Offset { x: s(x), d: dst, c },
                GOp::Leaf | GOp::Input | GOp::Composite { .. } | GOp::CompositeShared { .. } => {
                    unreachable!()
                }
            });
        }
    }
    if micro.len() > run_start {
        fwd.push(FwdInstr::Run {
            start: run_start as u32,
            len: (micro.len() - run_start) as u32,
        });
    }

    // -- pass 5: backward emission (adjoint re-slotting) ------------------
    let mut bwd: Vec<BwdInstr> = Vec::new();
    let mut edge_adj: Vec<u32> = Vec::new();
    let mut edge_partial: Vec<u32> = Vec::new();
    let mut adj_slot = vec![NONE; n];
    let mut next_adj: u32 = 0;
    let mut free_adj: Vec<u32> = Vec::new();

    // input adjoints first: persistent registers, zeroed every sweep so
    // gradient-unreachable inputs read back 0.0 like the interpreter's
    let mut input_adj_slots = Vec::with_capacity(inp.inputs.len());
    for &id in inp.inputs {
        let s = next_adj;
        next_adj += 1;
        adj_slot[id as usize] = s;
        input_adj_slots.push(s);
        bwd.push(BwdInstr::Zero { a: s });
    }
    // seed the output (after the zeros: output-is-input must end at 1.0)
    let oa = if adj_slot[out] != NONE {
        adj_slot[out]
    } else {
        let s = next_adj;
        next_adj += 1;
        adj_slot[out] = s;
        s
    };
    bwd.push(BwdInstr::Seed { a: oa });

    for i in (0..n).rev() {
        if !bwd_emit[i] {
            continue;
        }
        let a = adj_slot[i];
        debug_assert!(
            a != NONE,
            "live gradient-relevant node {} has no adjoint register",
            i
        );
        let vs = |p: u32| {
            debug_assert!(val_slot[p as usize] != NONE);
            val_slot[p as usize]
        };
        match inp.ops[i] {
            GOp::Leaf | GOp::Input => unreachable!(),
            GOp::Add(x, y) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let ay = ensure_adj(y as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Add { a, ax, ay });
            }
            GOp::Sub(x, y) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let ay = ensure_adj(y as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Sub { a, ax, ay });
            }
            GOp::Mul(x, y) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let ay = ensure_adj(y as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let vx = if ay != NONE { vs(x) } else { NONE };
                let vy = if ax != NONE { vs(y) } else { NONE };
                bwd.push(BwdInstr::Mul { a, ax, ay, vx, vy });
            }
            GOp::Div(x, y) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let ay = ensure_adj(y as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                let vx = if ay != NONE { vs(x) } else { NONE };
                let vy = if ax != NONE || ay != NONE { vs(y) } else { NONE };
                bwd.push(BwdInstr::Div { a, ax, ay, vx, vy });
            }
            GOp::Neg(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Neg { a, ax });
            }
            GOp::Exp(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Exp { a, ax, v: val_slot[i] });
            }
            GOp::Sqrt(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Sqrt { a, ax, v: val_slot[i] });
            }
            GOp::Sigmoid(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Sigmoid { a, ax, v: val_slot[i] });
            }
            GOp::Tanh(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Tanh { a, ax, v: val_slot[i] });
            }
            GOp::Ln(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Ln { a, ax, vx: vs(x) });
            }
            GOp::Log1p(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Log1p { a, ax, vx: vs(x) });
            }
            GOp::Softplus(x) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Softplus { a, ax, vx: vs(x) });
            }
            GOp::Powi(x, pn) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Powi { a, ax, vx: vs(x), n: pn });
            }
            GOp::Scale(x, c) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Scale { a, ax, c });
            }
            GOp::Offset(x, _) => {
                let ax = ensure_adj(x as usize, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                bwd.push(BwdInstr::Offset { a, ax });
            }
            GOp::Composite { pstart, xstart, len } => {
                let estart = edge_adj.len() as u32;
                for j in 0..len as usize {
                    let p = inp.arena_parents[pstart as usize + j] as usize;
                    if !grad_rel[p] {
                        continue; // pruned: this adjoint never reaches an input
                    }
                    let pa = ensure_adj(p, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                    edge_adj.push(pa);
                    edge_partial.push(xstart + j as u32);
                }
                let elen = edge_adj.len() as u32 - estart;
                bwd.push(BwdInstr::Composite { a, estart, elen });
            }
            GOp::CompositeShared { pstart, sstart, len } => {
                let estart = edge_adj.len() as u32;
                for j in 0..len as usize {
                    let p = inp.arena_parents[pstart as usize + j] as usize;
                    if !grad_rel[p] {
                        continue;
                    }
                    let pa = ensure_adj(p, &grad_rel, &mut adj_slot, &mut free_adj, &mut next_adj, &mut bwd);
                    edge_adj.push(pa);
                    edge_partial.push(sstart + j as u32);
                }
                let elen = edge_adj.len() as u32 - estart;
                bwd.push(BwdInstr::CompositeShared { a, estart, elen });
            }
        }
        // this node's adjoint is fully consumed (descending order);
        // recycle its register only *after* the instruction above, so a
        // parent's alloc+Zero can never clobber it in the stream
        free_adj.push(a);
    }

    // -- assembly ---------------------------------------------------------
    let mut init_values: Vec<(u32, f64)> = Vec::new();
    for i in 0..n {
        if constify[i] || (is_slot_node[i] && !recompute[i]) {
            init_values.push((val_slot[i], inp.rec_values[i]));
        }
    }
    let input_val_slots: Vec<u32> = inp.inputs.iter().map(|&id| val_slot[id as usize]).collect();
    let slot_node_slots: Vec<u32> = inp
        .slot_nodes
        .iter()
        .map(|&id| val_slot[id as usize])
        .collect();

    let fused_runs = fwd
        .iter()
        .filter(|f| matches!(f, FwdInstr::Run { .. }))
        .count();
    let stats = PlanStats {
        nodes_total: n,
        nodes_live: live.iter().filter(|&&b| b).count(),
        nodes_folded: constify.iter().filter(|&&b| b).count(),
        fused_runs,
        micro_ops: micro.len(),
        composites: fwd.len() - fused_runs,
        fwd_instrs: fwd.len(),
        bwd_instrs: bwd.len(),
        peak_val_slots: next_val as usize,
        peak_adj_slots: next_adj as usize,
    };

    ExecPlan {
        fwd,
        micro,
        bwd,
        edge_adj,
        edge_partial,
        parents: parents_map,
        init_values,
        input_val_slots,
        input_adj_slots,
        output_val_slot: val_slot[out],
        data_slots: inp.data_slots.to_vec(),
        slot_node_slots,
        num_val_slots: next_val as usize,
        num_adj_slots: next_adj as usize,
        stats,
    }
}
