//! Multi-lane (struct-of-arrays) reverse-mode tape: the autodiff
//! substrate of the **vectorized chain engine**.
//!
//! A [`BatchTape`] is the K-lane generalization of [`crate::autodiff::Tape`]:
//! every node holds `lanes` primal values laid out contiguously
//! (`values[node * lanes + k]` is lane `k`), and one reverse sweep
//! produces `lanes` independent gradients.  This is NumPyro's
//! `vmap`-over-`potential_fn` trick done natively: the op list — the
//! expensive interpretive part of taped autodiff — is recorded **once**
//! per evaluation, while the per-op arithmetic runs over short
//! contiguous f64 arrays that the autovectorizer turns into SIMD
//! (4/8-wide on AVX2/AVX-512).
//!
//! # Lane semantics
//!
//! Each lane is an *independent* scalar evaluation: lane `k` of every
//! node is a pure function of lane `k` of the leaf inputs, with the
//! exact same operation sequence, branch structure and accumulation
//! order as the scalar [`crate::autodiff::Tape`].  Consequently a
//! program replayed on a
//! `BatchTape` produces, per lane, **bitwise-identical** values and
//! gradients to the same program replayed on a scalar tape — the
//! invariant the cross-method golden tests
//! (`rust/tests/chain_methods.rs`) pin down.  The reverse sweep
//! preserves even the scalar tape's zero-adjoint skip per lane (a lane
//! whose adjoint is exactly `0.0` receives no `+=` at all, so signed
//! zeros and non-finite partials propagate identically).
//!
//! Like the scalar tape, all storage is reused across evaluations:
//! [`BatchTape::reset`] keeps every buffer's capacity, so steady-state
//! batched gradient evaluations perform zero heap allocations
//! (`rust/tests/alloc_free.rs` proves it with a counting allocator).

use crate::autodiff::{Alg, Var};

/// Node operation of the batched tape.  Mirrors the scalar tape's op
/// set; composite partials live out-of-line in one of two arenas:
/// per-lane (`Composite`, used by fused likelihoods whose partials
/// differ per chain) or shared-across-lanes (`CompositeShared`, used by
/// `sum`/`dot_const` whose partials are data constants).
#[derive(Debug, Clone, Copy)]
enum BOp {
    Leaf,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Powi(u32, i32),
    Scale(u32, f64),
    Offset(u32),
    /// Parents at `arena_parents[pstart..pstart+len]`, per-lane partials
    /// at `arena_partials[(xstart + j) * lanes + k]`.
    Composite { pstart: u32, xstart: u32, len: u32 },
    /// Parents at `arena_parents[pstart..pstart+len]`, lane-shared
    /// partials at `arena_shared[sstart + j]`.
    CompositeShared { pstart: u32, sstart: u32, len: u32 },
}

/// K-lane reverse-mode tape (see the module docs).  Build the
/// expression with the `BatchTape` methods (or generically through its
/// [`Alg`] impl), then call [`BatchTape::grad`] on the output node.
pub struct BatchTape {
    lanes: usize,
    ops: Vec<BOp>,
    /// node-major, lane-minor: `values[node * lanes + k]`
    values: Vec<f64>,
    arena_parents: Vec<u32>,
    /// per-lane composite partials, parent-slot-major lane-minor
    arena_partials: Vec<f64>,
    /// lane-shared composite partials
    arena_shared: Vec<f64>,
    /// adjoint scratch for the reverse sweep
    adj: Vec<f64>,
    /// lane-sized accumulator scratch for `sum` / `dot_const`
    scratch: Vec<f64>,
}

impl BatchTape {
    pub fn new(lanes: usize) -> BatchTape {
        assert!(lanes > 0, "BatchTape needs at least one lane");
        BatchTape {
            lanes,
            ops: Vec::with_capacity(1024),
            values: Vec::with_capacity(1024 * lanes),
            arena_parents: Vec::with_capacity(1024),
            arena_partials: Vec::with_capacity(1024),
            arena_shared: Vec::with_capacity(1024),
            adj: Vec::new(),
            scratch: vec![0.0; lanes],
        }
    }

    /// Number of independent evaluation lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Clear the tape for the next evaluation, keeping every buffer's
    /// capacity (the zero-allocation steady state).
    pub fn reset(&mut self) {
        self.ops.clear();
        self.values.clear();
        self.arena_parents.clear();
        self.arena_partials.clear();
        self.arena_shared.clear();
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Node-storage capacity watermark (regression guard for reuse).
    pub fn node_capacity(&self) -> usize {
        self.values.capacity()
    }

    /// Per-lane composite-arena capacity watermark.
    pub fn arena_capacity(&self) -> usize {
        self.arena_partials.capacity()
    }

    /// All `lanes` primal values of node `v`.
    #[inline]
    pub fn lane_values(&self, v: Var) -> &[f64] {
        let s = v.0 as usize * self.lanes;
        &self.values[s..s + self.lanes]
    }

    /// Primal value of node `v` in lane `k`.
    #[inline]
    pub fn value_at(&self, v: Var, k: usize) -> f64 {
        self.values[v.0 as usize * self.lanes + k]
    }

    /// Differentiable input leaf with per-lane values.
    pub fn input(&mut self, vals: &[f64]) -> Var {
        assert_eq!(vals.len(), self.lanes, "input: lane-count mismatch");
        let idx = self.ops.len() as u32;
        self.ops.push(BOp::Leaf);
        self.values.extend_from_slice(vals);
        Var(idx)
    }

    /// Constant leaf, broadcast to every lane.
    pub fn constant(&mut self, c: f64) -> Var {
        let idx = self.ops.len() as u32;
        self.ops.push(BOp::Leaf);
        self.values.resize(self.values.len() + self.lanes, c);
        Var(idx)
    }

    /// Push a unary node computing `f` lane-wise from parent `a`.
    #[inline]
    fn unary(&mut self, op: BOp, a: Var, f: impl Fn(f64) -> f64) -> Var {
        let l = self.lanes;
        let idx = self.ops.len();
        self.ops.push(op);
        self.values.resize((idx + 1) * l, 0.0);
        let (src, dst) = self.values.split_at_mut(idx * l);
        let pa = &src[a.0 as usize * l..a.0 as usize * l + l];
        for k in 0..l {
            dst[k] = f(pa[k]);
        }
        Var(idx as u32)
    }

    /// Push a binary node computing `f` lane-wise from parents `a`, `b`.
    #[inline]
    fn binary(&mut self, op: BOp, a: Var, b: Var, f: impl Fn(f64, f64) -> f64) -> Var {
        let l = self.lanes;
        let idx = self.ops.len();
        self.ops.push(op);
        self.values.resize((idx + 1) * l, 0.0);
        let (src, dst) = self.values.split_at_mut(idx * l);
        let pa = &src[a.0 as usize * l..a.0 as usize * l + l];
        let pb = &src[b.0 as usize * l..b.0 as usize * l + l];
        for k in 0..l {
            dst[k] = f(pa[k], pb[k]);
        }
        Var(idx as u32)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Add(a.0, b.0), a, b, |x, y| x + y)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Sub(a.0, b.0), a, b, |x, y| x - y)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Mul(a.0, b.0), a, b, |x, y| x * y)
    }

    pub fn div(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Div(a.0, b.0), a, b, |x, y| x / y)
    }

    pub fn neg(&mut self, a: Var) -> Var {
        self.unary(BOp::Neg(a.0), a, |x| -x)
    }

    pub fn exp(&mut self, a: Var) -> Var {
        self.unary(BOp::Exp(a.0), a, f64::exp)
    }

    pub fn ln(&mut self, a: Var) -> Var {
        self.unary(BOp::Ln(a.0), a, f64::ln)
    }

    pub fn log1p(&mut self, a: Var) -> Var {
        self.unary(BOp::Log1p(a.0), a, f64::ln_1p)
    }

    pub fn sqrt(&mut self, a: Var) -> Var {
        self.unary(BOp::Sqrt(a.0), a, f64::sqrt)
    }

    /// Lane-wise logistic sigmoid — same branch structure as
    /// [`crate::autodiff::Tape::sigmoid`] so the lanes agree bitwise.
    pub fn sigmoid(&mut self, a: Var) -> Var {
        self.unary(BOp::Sigmoid(a.0), a, |x| {
            if x >= 0.0 {
                1.0 / (1.0 + (-x).exp())
            } else {
                let e = x.exp();
                e / (1.0 + e)
            }
        })
    }

    /// Lane-wise `log(1 + e^x)` — same branch structure as
    /// [`crate::autodiff::Tape::softplus`].
    pub fn softplus(&mut self, a: Var) -> Var {
        self.unary(BOp::Softplus(a.0), a, |x| {
            if x > 30.0 {
                x
            } else {
                x.exp().ln_1p()
            }
        })
    }

    pub fn powi(&mut self, a: Var, n: i32) -> Var {
        self.unary(BOp::Powi(a.0, n), a, |x| x.powi(n))
    }

    pub fn square(&mut self, a: Var) -> Var {
        self.powi(a, 2)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        self.unary(BOp::Scale(a.0, c), a, |x| c * x)
    }

    pub fn offset(&mut self, a: Var, c: f64) -> Var {
        self.unary(BOp::Offset(a.0), a, |x| x + c)
    }

    /// Push a composite node with caller-supplied per-lane `values`
    /// (length `lanes`) from the tape's scratch-independent buffers.
    fn push_composite(&mut self, op: BOp, values: &[f64]) -> Var {
        debug_assert_eq!(values.len(), self.lanes);
        let idx = self.ops.len() as u32;
        self.ops.push(op);
        self.values.extend_from_slice(values);
        Var(idx)
    }

    /// Fused primitive with **per-lane** partials: `values[k]` is the
    /// node's value in lane `k`, `partials[j * lanes + k]` is
    /// `d value_k / d parents[j]_k`.  The batched counterpart of
    /// [`crate::autodiff::Tape::composite`].
    pub fn composite_lanes(&mut self, parents: &[Var], partials: &[f64], values: &[f64]) -> Var {
        assert_eq!(partials.len(), parents.len() * self.lanes);
        let pstart = self.arena_parents.len() as u32;
        let xstart = (self.arena_partials.len() / self.lanes) as u32;
        self.arena_parents.extend(parents.iter().map(|v| v.0));
        self.arena_partials.extend_from_slice(partials);
        self.push_composite(
            BOp::Composite {
                pstart,
                xstart,
                len: parents.len() as u32,
            },
            values,
        )
    }

    /// Fused primitive whose partials are the same in every lane
    /// (data-constant coefficients): `partials[j]` applies to all lanes
    /// of `parents[j]`.
    pub fn composite_shared(&mut self, parents: &[Var], partials: &[f64], values: &[f64]) -> Var {
        assert_eq!(partials.len(), parents.len());
        let pstart = self.arena_parents.len() as u32;
        let sstart = self.arena_shared.len() as u32;
        self.arena_parents.extend(parents.iter().map(|v| v.0));
        self.arena_shared.extend_from_slice(partials);
        self.push_composite(
            BOp::CompositeShared {
                pstart,
                sstart,
                len: parents.len() as u32,
            },
            values,
        )
    }

    /// Lane-wise sum over `xs`, accumulated in slice order per lane —
    /// the same order as [`crate::autodiff::Tape::sum`], so each lane
    /// matches the scalar tape bitwise.
    pub fn sum(&mut self, xs: &[Var]) -> Var {
        let l = self.lanes;
        self.scratch.clear();
        self.scratch.resize(l, 0.0);
        for v in xs {
            let s = v.0 as usize * l;
            for k in 0..l {
                self.scratch[k] += self.values[s + k];
            }
        }
        let pstart = self.arena_parents.len() as u32;
        let sstart = self.arena_shared.len() as u32;
        self.arena_parents.extend(xs.iter().map(|v| v.0));
        self.arena_shared
            .resize(self.arena_shared.len() + xs.len(), 1.0);
        let op = BOp::CompositeShared {
            pstart,
            sstart,
            len: xs.len() as u32,
        };
        let idx = self.ops.len() as u32;
        self.ops.push(op);
        // move scratch into the value store without re-borrowing self
        let start = self.values.len();
        self.values.resize(start + l, 0.0);
        self.values[start..start + l].copy_from_slice(&self.scratch);
        Var(idx)
    }

    /// Lane-wise `dot(ws, cs)` for constant coefficients `cs`,
    /// accumulated in slice order per lane (matches
    /// [`crate::autodiff::Tape::dot_const`] bitwise per lane).
    pub fn dot_const(&mut self, ws: &[Var], cs: &[f64]) -> Var {
        assert_eq!(ws.len(), cs.len());
        let l = self.lanes;
        self.scratch.clear();
        self.scratch.resize(l, 0.0);
        for (v, &c) in ws.iter().zip(cs) {
            let s = v.0 as usize * l;
            for k in 0..l {
                self.scratch[k] += self.values[s + k] * c;
            }
        }
        let pstart = self.arena_parents.len() as u32;
        let sstart = self.arena_shared.len() as u32;
        self.arena_parents.extend(ws.iter().map(|v| v.0));
        self.arena_shared.extend_from_slice(cs);
        let op = BOp::CompositeShared {
            pstart,
            sstart,
            len: ws.len() as u32,
        };
        let idx = self.ops.len() as u32;
        self.ops.push(op);
        let start = self.values.len();
        self.values.resize(start + l, 0.0);
        self.values[start..start + l].copy_from_slice(&self.scratch);
        Var(idx)
    }

    /// Reverse sweep from `output`: returns the adjoints of every node,
    /// node-major lane-minor (`adj[node * lanes + k]`).  Per lane this
    /// performs exactly the scalar tape's sweep, including the
    /// zero-adjoint skip, so each lane's gradient is bitwise equal to a
    /// scalar-tape replay of the same program.
    pub fn grad(&mut self, output: Var) -> &[f64] {
        let n = self.ops.len();
        let l = self.lanes;
        self.adj.clear();
        self.adj.resize(n * l, 0.0);
        {
            let o = output.0 as usize * l;
            for a in &mut self.adj[o..o + l] {
                *a = 1.0;
            }
        }
        let BatchTape {
            ops,
            values,
            arena_parents,
            arena_partials,
            arena_shared,
            adj,
            ..
        } = self;
        for i in (0..n).rev() {
            let (front, back) = adj.split_at_mut(i * l);
            let a = &back[..l];
            if a.iter().all(|&x| x == 0.0) {
                continue;
            }
            let vi = &values[i * l..(i + 1) * l];
            match ops[i] {
                BOp::Leaf => {}
                BOp::Add(x, y) => {
                    let (xs, ys) = (x as usize * l, y as usize * l);
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak;
                        }
                    }
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[ys + k] += ak;
                        }
                    }
                }
                BOp::Sub(x, y) => {
                    let (xs, ys) = (x as usize * l, y as usize * l);
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak;
                        }
                    }
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[ys + k] -= ak;
                        }
                    }
                }
                BOp::Mul(x, y) => {
                    let (xs, ys) = (x as usize * l, y as usize * l);
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak * values[ys + k];
                        }
                    }
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[ys + k] += ak * values[xs + k];
                        }
                    }
                }
                BOp::Div(x, y) => {
                    let (xs, ys) = (x as usize * l, y as usize * l);
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak / values[ys + k];
                        }
                    }
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            let vy = values[ys + k];
                            front[ys + k] -= ak * values[xs + k] / (vy * vy);
                        }
                    }
                }
                BOp::Neg(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] -= ak;
                        }
                    }
                }
                BOp::Exp(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak * vi[k];
                        }
                    }
                }
                BOp::Ln(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak / values[xs + k];
                        }
                    }
                }
                BOp::Log1p(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak / (1.0 + values[xs + k]);
                        }
                    }
                }
                BOp::Sqrt(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak * 0.5 / vi[k];
                        }
                    }
                }
                BOp::Sigmoid(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak * vi[k] * (1.0 - vi[k]);
                        }
                    }
                }
                BOp::Softplus(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            let xv = values[xs + k];
                            let s = if xv >= 0.0 {
                                1.0 / (1.0 + (-xv).exp())
                            } else {
                                let e = xv.exp();
                                e / (1.0 + e)
                            };
                            front[xs + k] += ak * s;
                        }
                    }
                }
                BOp::Powi(x, pn) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            let xv = values[xs + k];
                            front[xs + k] += ak * (pn as f64) * xv.powi(pn - 1);
                        }
                    }
                }
                BOp::Scale(x, c) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak * c;
                        }
                    }
                }
                BOp::Offset(x) => {
                    let xs = x as usize * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[xs + k] += ak;
                        }
                    }
                }
                BOp::Composite { pstart, xstart, len } => {
                    for j in 0..len as usize {
                        let parent = arena_parents[pstart as usize + j] as usize * l;
                        let ps = (xstart as usize + j) * l;
                        for k in 0..l {
                            let ak = a[k];
                            if ak != 0.0 {
                                front[parent + k] += ak * arena_partials[ps + k];
                            }
                        }
                    }
                }
                BOp::CompositeShared { pstart, sstart, len } => {
                    for j in 0..len as usize {
                        let parent = arena_parents[pstart as usize + j] as usize * l;
                        let p = arena_shared[sstart as usize + j];
                        for k in 0..l {
                            let ak = a[k];
                            if ak != 0.0 {
                                front[parent + k] += ak * p;
                            }
                        }
                    }
                }
            }
        }
        &self.adj
    }
}

/// The batched tape is an [`Alg`] instance: the *same* generic model
/// code that replays on a scalar [`crate::autodiff::Tape`] replays here
/// once for all lanes.  [`Alg::lit`] broadcasts a constant to every
/// lane.  [`Alg::val`] is **not lane-meaningful** with more than one
/// lane — a node holds K independent primals, so returning any single
/// one would silently violate the lane-independence contract for model
/// code that branches on it.  It therefore panics for `lanes > 1`
/// (models that read primal values must use [`BatchTape::lane_values`]
/// / [`BatchTape::value_at`], or fall back to
/// [`crate::mcmc::ScalarLanes`] over the scalar compiler).
impl Alg for BatchTape {
    type V = Var;

    fn lit(&mut self, x: f64) -> Var {
        self.constant(x)
    }
    fn val(&self, v: Var) -> f64 {
        assert!(
            self.lanes == 1,
            "Alg::val on a {}-lane BatchTape: a node has one primal per lane; \
             use lane_values()/value_at() per lane, or sample this model through \
             ScalarLanes instead of the batched compiler",
            self.lanes
        );
        self.value_at(v, 0)
    }
    fn add(&mut self, a: Var, b: Var) -> Var {
        BatchTape::add(self, a, b)
    }
    fn sub(&mut self, a: Var, b: Var) -> Var {
        BatchTape::sub(self, a, b)
    }
    fn mul(&mut self, a: Var, b: Var) -> Var {
        BatchTape::mul(self, a, b)
    }
    fn div(&mut self, a: Var, b: Var) -> Var {
        BatchTape::div(self, a, b)
    }
    fn neg(&mut self, a: Var) -> Var {
        BatchTape::neg(self, a)
    }
    fn exp(&mut self, a: Var) -> Var {
        BatchTape::exp(self, a)
    }
    fn ln(&mut self, a: Var) -> Var {
        BatchTape::ln(self, a)
    }
    fn log1p(&mut self, a: Var) -> Var {
        BatchTape::log1p(self, a)
    }
    fn sqrt(&mut self, a: Var) -> Var {
        BatchTape::sqrt(self, a)
    }
    fn softplus(&mut self, a: Var) -> Var {
        BatchTape::softplus(self, a)
    }
    fn powi(&mut self, a: Var, n: i32) -> Var {
        BatchTape::powi(self, a, n)
    }
    fn scale(&mut self, a: Var, c: f64) -> Var {
        BatchTape::scale(self, a, c)
    }
    fn offset(&mut self, a: Var, c: f64) -> Var {
        BatchTape::offset(self, a, c)
    }
    fn square(&mut self, a: Var) -> Var {
        BatchTape::square(self, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Tape;

    /// A program touching every Alg op (shared with the scalar-tape
    /// bitwise test in `autodiff::tests`).
    fn alg_program<A: Alg>(a: &mut A, x: A::V, y: A::V) -> A::V {
        let s = a.add(x, y);
        let e = a.exp(s);
        let lg = a.log1p(e);
        let q = a.square(x);
        let sc = a.scale(q, -0.5);
        let sp = a.softplus(y);
        let d = a.div(sc, sp);
        let m = a.mul(lg, d);
        let sq = a.sqrt(e);
        let ng = a.neg(sq);
        let o = a.offset(m, 0.25);
        let p = a.powi(y, 3);
        let t = a.sub(o, ng);
        let ln = a.ln(e);
        let u = a.add(t, p);
        a.add(u, ln)
    }

    /// Every lane of the batched tape must agree **bitwise** with a
    /// scalar-tape evaluation of the same program at that lane's
    /// inputs, for both primal values and gradients.
    #[test]
    fn lanes_match_scalar_tape_bitwise() {
        let xs = [0.3, 2.0, -0.7, 1.9];
        let ys = [-1.2, 0.5, 31.5, -0.1];
        let lanes = xs.len();

        let mut bt = BatchTape::new(lanes);
        let bx = bt.input(&xs);
        let by = bt.input(&ys);
        let bout = alg_program(&mut bt, bx, by);
        let bvals = bt.lane_values(bout).to_vec();
        let badj = bt.grad(bout).to_vec();

        for k in 0..lanes {
            let mut t = Tape::new();
            let vx = t.input(xs[k]);
            let vy = t.input(ys[k]);
            let out = alg_program(&mut t, vx, vy);
            assert_eq!(t.value(out), bvals[k], "lane {k} primal");
            let adj = t.grad(out);
            assert_eq!(
                adj[vx.0 as usize],
                badj[bx.0 as usize * lanes + k],
                "lane {k} d/dx"
            );
            assert_eq!(
                adj[vy.0 as usize],
                badj[by.0 as usize * lanes + k],
                "lane {k} d/dy"
            );
        }
    }

    #[test]
    fn sum_and_dot_const_match_scalar_bitwise() {
        let rows = [[0.3, -1.2, 0.9], [1.4, 0.2, -0.5]];
        let coef = [0.5, -1.5, 2.0];
        let lanes = 2;
        let mut bt = BatchTape::new(lanes);
        let vars: Vec<Var> = (0..3)
            .map(|i| bt.input(&[rows[0][i], rows[1][i]]))
            .collect();
        let s = bt.sum(&vars);
        let d = bt.dot_const(&vars, &coef);
        let out = bt.mul(s, d);
        let bvals = bt.lane_values(out).to_vec();
        let badj = bt.grad(out).to_vec();

        for k in 0..lanes {
            let mut t = Tape::new();
            let tv: Vec<Var> = rows[k].iter().map(|&v| t.input(v)).collect();
            let ts = t.sum(&tv);
            let td = t.dot_const(&tv, &coef);
            let tout = t.mul(ts, td);
            assert_eq!(t.value(tout), bvals[k], "lane {k} primal");
            let adj = t.grad(tout);
            for i in 0..3 {
                assert_eq!(
                    adj[tv[i].0 as usize],
                    badj[vars[i].0 as usize * lanes + k],
                    "lane {k} grad[{i}]"
                );
            }
        }
    }

    #[test]
    fn composite_lanes_partials_flow_per_lane() {
        // lane-dependent fused node: value_k = c_k * x_k with partial c_k
        let lanes = 3;
        let xs = [1.5, -2.0, 0.25];
        let cs = [2.0, 3.0, -4.0];
        let mut bt = BatchTape::new(lanes);
        let x = bt.input(&xs);
        let vals: Vec<f64> = (0..lanes).map(|k| cs[k] * xs[k]).collect();
        let node = bt.composite_lanes(&[x], &cs, &vals);
        let adj = bt.grad(node).to_vec();
        for k in 0..lanes {
            assert_eq!(adj[x.0 as usize * lanes + k], cs[k]);
        }
    }

    #[test]
    fn reset_keeps_capacity_watermark() {
        let mut bt = BatchTape::new(4);
        let xs = [0.1, 0.2, 0.3, 0.4];
        let ys = [0.5, -0.6, 0.7, -0.8];
        let x = bt.input(&xs);
        let y = bt.input(&ys);
        let out = alg_program(&mut bt, x, y);
        let _ = bt.grad(out);
        let (nodes, arena) = (bt.node_capacity(), bt.arena_capacity());
        for _ in 0..10 {
            bt.reset();
            let x = bt.input(&xs);
            let y = bt.input(&ys);
            let out = alg_program(&mut bt, x, y);
            let _ = bt.grad(out);
            assert_eq!(bt.node_capacity(), nodes);
            assert_eq!(bt.arena_capacity(), arena);
        }
    }
}
