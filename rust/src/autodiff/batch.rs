//! Multi-lane (struct-of-arrays) reverse-mode tape: the autodiff
//! substrate of the **vectorized chain engine**.
//!
//! A [`BatchTape`] is the K-lane generalization of [`crate::autodiff::Tape`]:
//! every node holds `lanes` primal values laid out contiguously
//! (`values[node * lanes + k]` is lane `k`), and one reverse sweep
//! produces `lanes` independent gradients.  This is NumPyro's
//! `vmap`-over-`potential_fn` trick done natively: the op list — the
//! expensive interpretive part of taped autodiff — is recorded **once**
//! per evaluation, while the per-op arithmetic runs over short
//! contiguous f64 arrays that the autovectorizer turns into SIMD
//! (4/8-wide on AVX2/AVX-512).
//!
//! # Lane semantics
//!
//! Each lane is an *independent* scalar evaluation: lane `k` of every
//! node is a pure function of lane `k` of the leaf inputs, with the
//! exact same operation sequence, branch structure and accumulation
//! order as the scalar [`crate::autodiff::Tape`].  Consequently a
//! program replayed on a
//! `BatchTape` produces, per lane, **bitwise-identical** values and
//! gradients to the same program replayed on a scalar tape — the
//! invariant the cross-method golden tests
//! (`rust/tests/chain_methods.rs`) pin down.  The reverse sweep
//! preserves even the scalar tape's zero-adjoint skip per lane (a lane
//! whose adjoint is exactly `0.0` receives no `+=` at all, so signed
//! zeros and non-finite partials propagate identically).
//!
//! Like the scalar tape, all storage is reused across evaluations:
//! [`BatchTape::reset`] keeps every buffer's capacity, so steady-state
//! batched gradient evaluations perform zero heap allocations
//! (`rust/tests/alloc_free.rs` proves it with a counting allocator).
//!
//! # Record once, replay many
//!
//! Mirroring the scalar tape, the batched tape is split into a recorded
//! topology (`BTopology`) and per-evaluation value/adjoint storage,
//! and [`BatchTape::freeze`] snapshots the recorded program into a
//! [`BatchTapeProgram`]: a flat instruction stream whose lane-minor
//! [`BatchTapeProgram::forward`] sweep is a plain auto-vectorizable
//! loop with **no per-node interpretation** — fused observation
//! composites re-run the *same* kernel functions the record path used,
//! so per lane the frozen program is bitwise identical to a batched (or
//! scalar) tape replay.  Raw [`BatchTape::composite_lanes`] /
//! [`BatchTape::composite_shared`] nodes carry caller-computed partials
//! and cannot be frozen.

use crate::autodiff::{sigmoid_val, softplus_val, Alg, CompKind, DataSlot, SlotStore, Var};
use crate::ppl::special::{softplus_sigmoid, LN_2PI};

/// Node operation of the batched tape.  Mirrors the scalar tape's op
/// set; composite partials live out-of-line in one of two arenas:
/// per-lane (`Composite`, used by fused likelihoods whose partials
/// differ per chain) or shared-across-lanes (`CompositeShared`, used by
/// `sum`/`dot_const` whose partials are data constants).
#[derive(Debug, Clone, Copy)]
pub(super) enum BOp {
    /// Constant leaf: lane values fixed at record time.
    Leaf,
    /// Differentiable input leaf: lane values rebound on frozen replay.
    Input,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Powi(u32, i32),
    Scale(u32, f64),
    Offset(u32, f64),
    /// Parents at `arena_parents[pstart..pstart+len]`, per-lane partials
    /// at `arena_partials[(xstart + j) * lanes + k]`.
    Composite { pstart: u32, xstart: u32, len: u32 },
    /// Parents at `arena_parents[pstart..pstart+len]`, lane-shared
    /// partials at `arena_shared[sstart + j]`.
    CompositeShared { pstart: u32, sstart: u32, len: u32 },
}

/// The recorded half of a batched tape: op kinds, argument node ids,
/// composite parents, lane-shared constant partials, kernel descriptors
/// and observation constants — identical across evaluations of a
/// static-structure program.  [`BatchTape::freeze`] clones this into a
/// [`BatchTapeProgram`].
#[derive(Debug, Clone, Default)]
pub(super) struct BTopology {
    pub(super) ops: Vec<BOp>,
    pub(super) arena_parents: Vec<u32>,
    /// lane-shared composite partials (data constants)
    pub(super) arena_shared: Vec<f64>,
    /// kernel descriptor per composite node, in node order
    pub(super) comp_kinds: Vec<CompKind>,
    /// fused-kernel constant data (observations, known scales)
    pub(super) consts: Vec<f64>,
    /// node ids of input leaves, in record order
    pub(super) inputs: Vec<u32>,
    /// minibatch-rebindable data spans, in record order
    pub(super) data_slots: Vec<DataSlot>,
    /// node ids referenced by [`SlotStore::Nodes`] slots
    pub(super) slot_nodes: Vec<u32>,
}

/// K-lane reverse-mode tape (see the module docs).  Build the
/// expression with the `BatchTape` methods (or generically through its
/// [`Alg`] impl), then call [`BatchTape::grad`] on the output node.
pub struct BatchTape {
    lanes: usize,
    topo: BTopology,
    /// node-major, lane-minor: `values[node * lanes + k]`
    values: Vec<f64>,
    /// per-lane composite partials, parent-slot-major lane-minor
    arena_partials: Vec<f64>,
    /// adjoint scratch for the reverse sweep
    adj: Vec<f64>,
    /// lane-sized accumulator scratch (`sum` / `dot_const` / fused vals)
    scratch: Vec<f64>,
    /// lane-sized fused-kernel scratch (residual sums)
    scratch_a: Vec<f64>,
    /// lane-sized fused-kernel scratch (hoisted 1/sigma^2)
    scratch_b: Vec<f64>,
    /// while true, data-bearing builders register rebindable slots
    data_region: bool,
}

/// Recompute one batched composite's lane values and per-lane partials
/// from fresh parent values — the **one** kernel implementation shared
/// by the record-time builders and [`BatchTapeProgram::forward`], which
/// makes frozen batched replays bitwise identical to tape replays.
///
/// `values` holds every node *before* this composite (node-major,
/// lane-minor); this composite's per-lane partial span starts at
/// `xstart * lanes`.  Lane values are written to `vals` (length
/// `lanes`); `acc_a`/`acc_b` are lane-sized scratch.
#[allow(clippy::too_many_arguments)]
pub(super) fn batch_composite_forward(
    kind: CompKind,
    lanes: usize,
    pstart: usize,
    xstart: usize,
    parents: &[u32],
    consts: &[f64],
    values: &[f64],
    partials: &mut [f64],
    vals: &mut [f64],
    acc_a: &mut [f64],
    acc_b: &mut [f64],
) {
    let l = lanes;
    for v in vals.iter_mut() {
        *v = 0.0;
    }
    match kind {
        CompKind::Opaque | CompKind::Affine | CompKind::LogSumExp => {
            unreachable!("not a fused batched composite kind")
        }
        CompKind::NormalIid { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            let nf = n as f64;
            let loc = parents[pstart] as usize * l;
            let scale = parents[pstart + 1] as usize * l;
            for k in 0..l {
                let lv = values[loc + k];
                let sv = values[scale + k];
                let inv2 = 1.0 / (sv * sv);
                let mut value = 0.0;
                let mut sr = 0.0;
                let mut sr2 = 0.0;
                for &y in ys {
                    let r = y - lv;
                    value += -0.5 * r * r * inv2;
                    sr += r;
                    sr2 += r * r;
                }
                value += -nf * sv.ln() - 0.5 * nf * LN_2PI;
                vals[k] = value;
                partials[xstart * l + k] = sr * inv2;
                partials[(xstart + 1) * l + k] = sr2 / (sv * sv * sv) - nf / sv;
            }
        }
        CompKind::BernoulliIid { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            let nf = n as f64;
            let logits = parents[pstart] as usize * l;
            let sum_y: f64 = ys.iter().sum();
            for k in 0..l {
                let zl = values[logits + k];
                let (sp, sig) = softplus_sigmoid(zl);
                vals[k] = sum_y * zl - nf * sp;
                partials[xstart * l + k] = sum_y - nf * sig;
            }
        }
        CompKind::NormalPlate { c, n } => {
            let nn = n as usize;
            let ys = &consts[c as usize..c as usize + nn];
            let nf = n as f64;
            let scale = parents[pstart + nn] as usize * l;
            // per-lane running sum of squared residuals ...
            for a in acc_a.iter_mut() {
                *a = 0.0;
            }
            // ... and per-lane 1/sigma^2, hoisted out of the element
            // loop (same value the scalar kernel computes once)
            for k in 0..l {
                let sv = values[scale + k];
                acc_b[k] = 1.0 / (sv * sv);
            }
            for (i, &y) in ys.iter().enumerate() {
                let loc = parents[pstart + i] as usize * l;
                for k in 0..l {
                    let inv2 = acc_b[k];
                    let lv = values[loc + k];
                    let r = y - lv;
                    vals[k] += -0.5 * r * r * inv2;
                    acc_a[k] += r * r;
                    partials[(xstart + i) * l + k] = r * inv2;
                }
            }
            for k in 0..l {
                let sv = values[scale + k];
                vals[k] += -nf * sv.ln() - 0.5 * nf * LN_2PI;
                partials[(xstart + nn) * l + k] = acc_a[k] / (sv * sv * sv) - nf / sv;
            }
        }
        CompKind::NormalFixedPlate { c, n } => {
            let nn = n as usize;
            let sy = &consts[c as usize..c as usize + 2 * nn];
            for i in 0..nn {
                let s = sy[2 * i];
                let y = sy[2 * i + 1];
                let inv2 = 1.0 / (s * s);
                let loc = parents[pstart + i] as usize * l;
                for k in 0..l {
                    let lv = values[loc + k];
                    let r = y - lv;
                    vals[k] += -0.5 * r * r * inv2 - s.ln() - 0.5 * LN_2PI;
                    partials[(xstart + i) * l + k] = r * inv2;
                }
            }
        }
        CompKind::BernoulliPlate { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            for (i, &y) in ys.iter().enumerate() {
                let logits = parents[pstart + i] as usize * l;
                for k in 0..l {
                    let zl = values[logits + k];
                    let (sp, sig) = softplus_sigmoid(zl);
                    vals[k] += y * zl - sp;
                    partials[(xstart + i) * l + k] = y - sig;
                }
            }
        }
    }
}

/// The lane-minor reverse sweep over a flat batched op stream — shared
/// by [`BatchTape::grad`] and [`BatchTapeProgram::backward`] so the two
/// are bitwise identical by construction (including the per-lane
/// zero-adjoint skip).
fn batch_reverse_sweep(
    ops: &[BOp],
    values: &[f64],
    arena_parents: &[u32],
    arena_partials: &[f64],
    arena_shared: &[f64],
    adj: &mut [f64],
    lanes: usize,
) {
    let l = lanes;
    for i in (0..ops.len()).rev() {
        let (front, back) = adj.split_at_mut(i * l);
        let a = &back[..l];
        if a.iter().all(|&x| x == 0.0) {
            continue;
        }
        let vi = &values[i * l..(i + 1) * l];
        match ops[i] {
            BOp::Leaf | BOp::Input => {}
            BOp::Add(x, y) => {
                let (xs, ys) = (x as usize * l, y as usize * l);
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak;
                    }
                }
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[ys + k] += ak;
                    }
                }
            }
            BOp::Sub(x, y) => {
                let (xs, ys) = (x as usize * l, y as usize * l);
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak;
                    }
                }
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[ys + k] -= ak;
                    }
                }
            }
            BOp::Mul(x, y) => {
                let (xs, ys) = (x as usize * l, y as usize * l);
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak * values[ys + k];
                    }
                }
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[ys + k] += ak * values[xs + k];
                    }
                }
            }
            BOp::Div(x, y) => {
                let (xs, ys) = (x as usize * l, y as usize * l);
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak / values[ys + k];
                    }
                }
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        let vy = values[ys + k];
                        front[ys + k] -= ak * values[xs + k] / (vy * vy);
                    }
                }
            }
            BOp::Neg(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] -= ak;
                    }
                }
            }
            BOp::Exp(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak * vi[k];
                    }
                }
            }
            BOp::Ln(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak / values[xs + k];
                    }
                }
            }
            BOp::Log1p(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak / (1.0 + values[xs + k]);
                    }
                }
            }
            BOp::Sqrt(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak * 0.5 / vi[k];
                    }
                }
            }
            BOp::Sigmoid(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak * vi[k] * (1.0 - vi[k]);
                    }
                }
            }
            BOp::Softplus(x) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        let s = sigmoid_val(values[xs + k]);
                        front[xs + k] += ak * s;
                    }
                }
            }
            BOp::Powi(x, pn) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        let xv = values[xs + k];
                        front[xs + k] += ak * (pn as f64) * xv.powi(pn - 1);
                    }
                }
            }
            BOp::Scale(x, c) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak * c;
                    }
                }
            }
            BOp::Offset(x, _) => {
                let xs = x as usize * l;
                for k in 0..l {
                    let ak = a[k];
                    if ak != 0.0 {
                        front[xs + k] += ak;
                    }
                }
            }
            BOp::Composite { pstart, xstart, len } => {
                for j in 0..len as usize {
                    let parent = arena_parents[pstart as usize + j] as usize * l;
                    let ps = (xstart as usize + j) * l;
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[parent + k] += ak * arena_partials[ps + k];
                        }
                    }
                }
            }
            BOp::CompositeShared { pstart, sstart, len } => {
                for j in 0..len as usize {
                    let parent = arena_parents[pstart as usize + j] as usize * l;
                    let p = arena_shared[sstart as usize + j];
                    for k in 0..l {
                        let ak = a[k];
                        if ak != 0.0 {
                            front[parent + k] += ak * p;
                        }
                    }
                }
            }
        }
    }
}

impl BatchTape {
    pub fn new(lanes: usize) -> BatchTape {
        assert!(lanes > 0, "BatchTape needs at least one lane");
        BatchTape {
            lanes,
            topo: BTopology {
                ops: Vec::with_capacity(1024),
                arena_parents: Vec::with_capacity(1024),
                arena_shared: Vec::with_capacity(1024),
                comp_kinds: Vec::with_capacity(64),
                consts: Vec::with_capacity(256),
                inputs: Vec::with_capacity(64),
                data_slots: Vec::new(),
                slot_nodes: Vec::new(),
            },
            values: Vec::with_capacity(1024 * lanes),
            arena_partials: Vec::with_capacity(1024),
            adj: Vec::new(),
            scratch: vec![0.0; lanes],
            scratch_a: vec![0.0; lanes],
            scratch_b: vec![0.0; lanes],
            data_region: false,
        }
    }

    /// Number of independent evaluation lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Clear the tape *and* release its backing storage (see
    /// [`crate::autodiff::Tape::clear_and_shrink`]) — used by frozen
    /// batched models in release builds, where the recording tape is
    /// never consulted again.
    pub fn clear_and_shrink(&mut self) {
        self.reset();
        self.topo.ops.shrink_to_fit();
        self.topo.arena_parents.shrink_to_fit();
        self.topo.arena_shared.shrink_to_fit();
        self.topo.comp_kinds.shrink_to_fit();
        self.topo.consts.shrink_to_fit();
        self.topo.inputs.shrink_to_fit();
        self.topo.data_slots.shrink_to_fit();
        self.topo.slot_nodes.shrink_to_fit();
        self.values.shrink_to_fit();
        self.arena_partials.shrink_to_fit();
        self.adj = Vec::new();
    }

    /// Clear the tape for the next evaluation, keeping every buffer's
    /// capacity (the zero-allocation steady state).
    pub fn reset(&mut self) {
        self.topo.ops.clear();
        self.topo.arena_parents.clear();
        self.topo.arena_shared.clear();
        self.topo.comp_kinds.clear();
        self.topo.consts.clear();
        self.topo.inputs.clear();
        self.topo.data_slots.clear();
        self.topo.slot_nodes.clear();
        self.values.clear();
        self.arena_partials.clear();
        self.data_region = false;
    }

    pub fn len(&self) -> usize {
        self.topo.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.topo.ops.is_empty()
    }

    /// Node-storage capacity watermark (regression guard for reuse).
    pub fn node_capacity(&self) -> usize {
        self.values.capacity()
    }

    /// Per-lane composite-arena capacity watermark.
    pub fn arena_capacity(&self) -> usize {
        self.arena_partials.capacity()
    }

    /// All `lanes` primal values of node `v`.
    #[inline]
    pub fn lane_values(&self, v: Var) -> &[f64] {
        let s = v.0 as usize * self.lanes;
        &self.values[s..s + self.lanes]
    }

    /// Primal value of node `v` in lane `k`.
    #[inline]
    pub fn value_at(&self, v: Var, k: usize) -> f64 {
        self.values[v.0 as usize * self.lanes + k]
    }

    /// Differentiable input leaf with per-lane values.  Inputs are
    /// remembered in record order: they are the slots
    /// [`BatchTapeProgram::forward`] rebinds.
    pub fn input(&mut self, vals: &[f64]) -> Var {
        assert_eq!(vals.len(), self.lanes, "input: lane-count mismatch");
        let idx = self.topo.ops.len() as u32;
        self.topo.inputs.push(idx);
        self.topo.ops.push(BOp::Input);
        self.values.extend_from_slice(vals);
        Var(idx)
    }

    /// Constant leaf, broadcast to every lane.
    pub fn constant(&mut self, c: f64) -> Var {
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(BOp::Leaf);
        self.values.resize(self.values.len() + self.lanes, c);
        Var(idx)
    }

    /// Start a **data region** (see
    /// [`crate::autodiff::Tape::begin_data_region`]): until
    /// [`BatchTape::end_data_region`], data-bearing builders register
    /// rebindable [`DataSlot`]s that
    /// [`BatchTapeProgram::rebind_data_slot`] can later overwrite with
    /// a fresh minibatch — lane-uniform, since observation data is
    /// shared across lanes.
    pub fn begin_data_region(&mut self) {
        self.data_region = true;
    }

    /// End the active data region.
    pub fn end_data_region(&mut self) {
        self.data_region = false;
    }

    /// Number of rebindable data slots recorded so far.
    pub fn num_data_slots(&self) -> usize {
        self.topo.data_slots.len()
    }

    fn register_slot(&mut self, store: SlotStore, start: usize, len: usize) {
        if self.data_region {
            self.topo.data_slots.push(DataSlot {
                store,
                start: start as u32,
                len: len as u32,
            });
        }
    }

    /// Register previously pushed (lane-uniform) constant leaves as one
    /// rebindable node slot — the batched twin of
    /// [`crate::autodiff::Tape::register_data_nodes`].  No-op outside a
    /// data region.
    pub fn register_data_nodes(&mut self, nodes: &[Var]) {
        if !self.data_region {
            return;
        }
        let start = self.topo.slot_nodes.len();
        self.topo.slot_nodes.extend(nodes.iter().map(|v| v.0));
        self.topo.data_slots.push(DataSlot {
            store: SlotStore::Nodes,
            start: start as u32,
            len: nodes.len() as u32,
        });
    }

    /// Push a unary node computing `f` lane-wise from parent `a`.
    #[inline]
    fn unary(&mut self, op: BOp, a: Var, f: impl Fn(f64) -> f64) -> Var {
        let l = self.lanes;
        let idx = self.topo.ops.len();
        self.topo.ops.push(op);
        self.values.resize((idx + 1) * l, 0.0);
        let (src, dst) = self.values.split_at_mut(idx * l);
        let pa = &src[a.0 as usize * l..a.0 as usize * l + l];
        for k in 0..l {
            dst[k] = f(pa[k]);
        }
        Var(idx as u32)
    }

    /// Push a binary node computing `f` lane-wise from parents `a`, `b`.
    #[inline]
    fn binary(&mut self, op: BOp, a: Var, b: Var, f: impl Fn(f64, f64) -> f64) -> Var {
        let l = self.lanes;
        let idx = self.topo.ops.len();
        self.topo.ops.push(op);
        self.values.resize((idx + 1) * l, 0.0);
        let (src, dst) = self.values.split_at_mut(idx * l);
        let pa = &src[a.0 as usize * l..a.0 as usize * l + l];
        let pb = &src[b.0 as usize * l..b.0 as usize * l + l];
        for k in 0..l {
            dst[k] = f(pa[k], pb[k]);
        }
        Var(idx as u32)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Add(a.0, b.0), a, b, |x, y| x + y)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Sub(a.0, b.0), a, b, |x, y| x - y)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Mul(a.0, b.0), a, b, |x, y| x * y)
    }

    pub fn div(&mut self, a: Var, b: Var) -> Var {
        self.binary(BOp::Div(a.0, b.0), a, b, |x, y| x / y)
    }

    pub fn neg(&mut self, a: Var) -> Var {
        self.unary(BOp::Neg(a.0), a, |x| -x)
    }

    pub fn exp(&mut self, a: Var) -> Var {
        self.unary(BOp::Exp(a.0), a, f64::exp)
    }

    pub fn ln(&mut self, a: Var) -> Var {
        self.unary(BOp::Ln(a.0), a, f64::ln)
    }

    pub fn log1p(&mut self, a: Var) -> Var {
        self.unary(BOp::Log1p(a.0), a, f64::ln_1p)
    }

    pub fn sqrt(&mut self, a: Var) -> Var {
        self.unary(BOp::Sqrt(a.0), a, f64::sqrt)
    }

    /// Lane-wise logistic sigmoid — same branch structure as
    /// [`crate::autodiff::Tape::sigmoid`] so the lanes agree bitwise.
    pub fn sigmoid(&mut self, a: Var) -> Var {
        self.unary(BOp::Sigmoid(a.0), a, sigmoid_val)
    }

    /// Lane-wise `log(1 + e^x)` — same branch structure as
    /// [`crate::autodiff::Tape::softplus`].
    pub fn softplus(&mut self, a: Var) -> Var {
        self.unary(BOp::Softplus(a.0), a, softplus_val)
    }

    pub fn powi(&mut self, a: Var, n: i32) -> Var {
        self.unary(BOp::Powi(a.0, n), a, |x| x.powi(n))
    }

    pub fn square(&mut self, a: Var) -> Var {
        self.powi(a, 2)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        self.unary(BOp::Scale(a.0, c), a, |x| c * x)
    }

    pub fn offset(&mut self, a: Var, c: f64) -> Var {
        self.unary(BOp::Offset(a.0, c), a, |x| x + c)
    }

    /// Push a composite node with caller-supplied per-lane `values`
    /// (length `lanes`) from the tape's scratch-independent buffers.
    fn push_composite(&mut self, op: BOp, values: &[f64]) -> Var {
        debug_assert_eq!(values.len(), self.lanes);
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(op);
        self.values.extend_from_slice(values);
        Var(idx)
    }

    /// Fused primitive with **per-lane** partials: `values[k]` is the
    /// node's value in lane `k`, `partials[j * lanes + k]` is
    /// `d value_k / d parents[j]_k`.  The batched counterpart of
    /// [`crate::autodiff::Tape::composite`] — and like it, **not
    /// freezable** (caller-computed partials cannot be recomputed).
    pub fn composite_lanes(&mut self, parents: &[Var], partials: &[f64], values: &[f64]) -> Var {
        assert_eq!(partials.len(), parents.len() * self.lanes);
        let pstart = self.topo.arena_parents.len() as u32;
        let xstart = (self.arena_partials.len() / self.lanes) as u32;
        self.topo.arena_parents.extend(parents.iter().map(|v| v.0));
        self.arena_partials.extend_from_slice(partials);
        self.topo.comp_kinds.push(CompKind::Opaque);
        self.push_composite(
            BOp::Composite {
                pstart,
                xstart,
                len: parents.len() as u32,
            },
            values,
        )
    }

    /// Fused primitive whose partials are the same in every lane
    /// (data-constant coefficients): `partials[j]` applies to all lanes
    /// of `parents[j]`.  Not freezable (see [`BatchTape::composite_lanes`]).
    pub fn composite_shared(&mut self, parents: &[Var], partials: &[f64], values: &[f64]) -> Var {
        assert_eq!(partials.len(), parents.len());
        let pstart = self.topo.arena_parents.len() as u32;
        let sstart = self.topo.arena_shared.len() as u32;
        self.topo.arena_parents.extend(parents.iter().map(|v| v.0));
        self.topo.arena_shared.extend_from_slice(partials);
        self.topo.comp_kinds.push(CompKind::Opaque);
        self.push_composite(
            BOp::CompositeShared {
                pstart,
                sstart,
                len: parents.len() as u32,
            },
            values,
        )
    }

    /// Lane-wise sum over `xs`, accumulated in slice order per lane —
    /// the same order as [`crate::autodiff::Tape::sum`], so each lane
    /// matches the scalar tape bitwise.
    pub fn sum(&mut self, xs: &[Var]) -> Var {
        let l = self.lanes;
        self.scratch.clear();
        self.scratch.resize(l, 0.0);
        for v in xs {
            let s = v.0 as usize * l;
            for k in 0..l {
                self.scratch[k] += self.values[s + k];
            }
        }
        let pstart = self.topo.arena_parents.len() as u32;
        let sstart = self.topo.arena_shared.len() as u32;
        self.topo.arena_parents.extend(xs.iter().map(|v| v.0));
        self.topo
            .arena_shared
            .resize(self.topo.arena_shared.len() + xs.len(), 1.0);
        self.topo.comp_kinds.push(CompKind::Affine);
        let op = BOp::CompositeShared {
            pstart,
            sstart,
            len: xs.len() as u32,
        };
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(op);
        // move scratch into the value store without re-borrowing self
        let start = self.values.len();
        self.values.resize(start + l, 0.0);
        self.values[start..start + l].copy_from_slice(&self.scratch);
        Var(idx)
    }

    /// Lane-wise `dot(ws, cs)` for constant coefficients `cs`,
    /// accumulated in slice order per lane (matches
    /// [`crate::autodiff::Tape::dot_const`] bitwise per lane).
    pub fn dot_const(&mut self, ws: &[Var], cs: &[f64]) -> Var {
        assert_eq!(ws.len(), cs.len());
        let l = self.lanes;
        self.scratch.clear();
        self.scratch.resize(l, 0.0);
        for (v, &c) in ws.iter().zip(cs) {
            let s = v.0 as usize * l;
            for k in 0..l {
                self.scratch[k] += self.values[s + k] * c;
            }
        }
        let pstart = self.topo.arena_parents.len() as u32;
        let sstart = self.topo.arena_shared.len() as u32;
        self.register_slot(SlotStore::Coeffs, sstart as usize, ws.len());
        self.topo.arena_parents.extend(ws.iter().map(|v| v.0));
        self.topo.arena_shared.extend_from_slice(cs);
        self.topo.comp_kinds.push(CompKind::Affine);
        let op = BOp::CompositeShared {
            pstart,
            sstart,
            len: ws.len() as u32,
        };
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(op);
        let start = self.values.len();
        self.values.resize(start + l, 0.0);
        self.values[start..start + l].copy_from_slice(&self.scratch);
        Var(idx)
    }

    /// Record a replayable fused composite whose parents were just
    /// pushed onto the parent arena: reserve the per-lane partial span,
    /// run the shared kernel, and push the node.
    fn fused_lanes(&mut self, kind: CompKind, num_parents: usize) -> Var {
        let l = self.lanes;
        self.topo.comp_kinds.push(kind);
        let pstart = self.topo.arena_parents.len() - num_parents;
        let xstart = self.arena_partials.len() / l;
        self.arena_partials.resize((xstart + num_parents) * l, 0.0);
        let BatchTape {
            topo,
            values,
            arena_partials,
            scratch,
            scratch_a,
            scratch_b,
            ..
        } = self;
        batch_composite_forward(
            kind,
            l,
            pstart,
            xstart,
            &topo.arena_parents,
            &topo.consts,
            values,
            arena_partials,
            scratch,
            scratch_a,
            scratch_b,
        );
        let op = BOp::Composite {
            pstart: pstart as u32,
            xstart: xstart as u32,
            len: num_parents as u32,
        };
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(op);
        let start = self.values.len();
        self.values.resize(start + l, 0.0);
        self.values[start..start + l].copy_from_slice(&self.scratch);
        Var(idx)
    }

    /// Fused i.i.d. Normal observation plate, lane-wise (see
    /// [`crate::autodiff::Tape::normal_iid_obs`]).
    pub fn normal_iid_obs(&mut self, loc: Var, scale: Var, ys: &[f64]) -> Var {
        let c = self.topo.consts.len();
        let kind = CompKind::NormalIid {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.push(loc.0);
        self.topo.arena_parents.push(scale.0);
        self.fused_lanes(kind, 2)
    }

    /// Fused i.i.d. Bernoulli observation plate with one shared latent
    /// logit, lane-wise.
    pub fn bernoulli_logits_iid_obs(&mut self, logits: Var, ys: &[f64]) -> Var {
        let c = self.topo.consts.len();
        let kind = CompKind::BernoulliIid {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.push(logits.0);
        self.fused_lanes(kind, 1)
    }

    /// Fused Normal observation plate with per-element latent locations
    /// and a shared latent scale, lane-wise.
    pub fn normal_plate_obs(&mut self, locs: &[Var], scale: Var, ys: &[f64]) -> Var {
        assert_eq!(locs.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::NormalPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.extend(locs.iter().map(|v| v.0));
        self.topo.arena_parents.push(scale.0);
        self.fused_lanes(kind, locs.len() + 1)
    }

    /// Fused Normal observation plate with per-element latent locations
    /// and *known* per-element scales, lane-wise.
    pub fn normal_fixed_plate_obs(&mut self, locs: &[Var], sigmas: &[f64], ys: &[f64]) -> Var {
        assert_eq!(locs.len(), ys.len());
        assert_eq!(sigmas.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::NormalFixedPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        // the slot spans the whole interleaved [sigma_0, y_0, ...] region
        self.register_slot(SlotStore::Consts, c, 2 * ys.len());
        for (s, y) in sigmas.iter().zip(ys) {
            self.topo.consts.push(*s);
            self.topo.consts.push(*y);
        }
        self.topo.arena_parents.extend(locs.iter().map(|v| v.0));
        self.fused_lanes(kind, locs.len())
    }

    /// Fused Bernoulli observation plate with per-element latent
    /// logits, lane-wise.
    pub fn bernoulli_logits_plate_obs(&mut self, logits: &[Var], ys: &[f64]) -> Var {
        assert_eq!(logits.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::BernoulliPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.extend(logits.iter().map(|v| v.0));
        self.fused_lanes(kind, logits.len())
    }

    /// Reverse sweep from `output`: returns the adjoints of every node,
    /// node-major lane-minor (`adj[node * lanes + k]`).  Per lane this
    /// performs exactly the scalar tape's sweep, including the
    /// zero-adjoint skip, so each lane's gradient is bitwise equal to a
    /// scalar-tape replay of the same program.
    pub fn grad(&mut self, output: Var) -> &[f64] {
        let n = self.topo.ops.len();
        let l = self.lanes;
        self.adj.clear();
        self.adj.resize(n * l, 0.0);
        {
            let o = output.0 as usize * l;
            for a in &mut self.adj[o..o + l] {
                *a = 1.0;
            }
        }
        batch_reverse_sweep(
            &self.topo.ops,
            &self.values,
            &self.topo.arena_parents,
            &self.arena_partials,
            &self.topo.arena_shared,
            &mut self.adj,
            l,
        );
        &self.adj
    }

    /// Snapshot the recorded program into a [`BatchTapeProgram`] whose
    /// lane-minor forward/backward sweeps are bitwise identical (per
    /// lane) to replaying the same program on this tape, with `output`
    /// as the differentiated node.  Panics if the tape contains a raw
    /// (non-replayable) composite.
    pub fn freeze(&self, output: Var) -> BatchTapeProgram {
        assert!(
            (output.0 as usize) < self.topo.ops.len(),
            "freeze: output node out of range"
        );
        assert!(
            !self
                .topo
                .comp_kinds
                .iter()
                .any(|&k| matches!(k, CompKind::Opaque)),
            "BatchTape::freeze: tape contains a raw composite_lanes/composite_shared node \
             whose caller-computed partials cannot be recomputed; record fused likelihoods \
             through the replayable builders (normal_iid_obs, normal_plate_obs, ...) instead"
        );
        BatchTapeProgram {
            lanes: self.lanes,
            topo: self.topo.clone(),
            output: output.0,
            values: self.values.clone(),
            partials: self.arena_partials.clone(),
            adj: vec![0.0; self.topo.ops.len() * self.lanes],
            vals: vec![0.0; self.lanes],
            acc_a: vec![0.0; self.lanes],
            acc_b: vec![0.0; self.lanes],
        }
    }
}

/// A frozen batched tape: the recorded topology plus per-eval
/// lane-minor value/partial/adjoint storage.  The forward sweep is a
/// flat loop over op codes with contiguous lane inner loops (the
/// autovectorizer's favourite shape) and **no interpretation** — the
/// batched analog of `jax.jit` staging out the traced program.  Per
/// lane, forward/backward are bitwise identical to a batched (and
/// therefore scalar) tape replay of the same program.
pub struct BatchTapeProgram {
    pub(super) lanes: usize,
    pub(super) topo: BTopology,
    pub(super) output: u32,
    pub(super) values: Vec<f64>,
    pub(super) partials: Vec<f64>,
    adj: Vec<f64>,
    vals: Vec<f64>,
    acc_a: Vec<f64>,
    acc_b: Vec<f64>,
}

impl BatchTapeProgram {
    /// Number of independent evaluation lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of input slots ([`BatchTape::input`] calls at record time).
    pub fn num_inputs(&self) -> usize {
        self.topo.inputs.len()
    }

    /// Number of instructions in the frozen stream.
    pub fn len(&self) -> usize {
        self.topo.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.topo.ops.is_empty()
    }

    /// Lane values of the output node after the last [`forward`].
    ///
    /// [`forward`]: BatchTapeProgram::forward
    pub fn output_values(&self) -> &[f64] {
        let s = self.output as usize * self.lanes;
        &self.values[s..s + self.lanes]
    }

    /// Number of rebindable data slots recorded inside data regions
    /// (see [`BatchTape::begin_data_region`]).
    pub fn num_data_slots(&self) -> usize {
        self.topo.data_slots.len()
    }

    /// Element count of data slot `slot`.
    pub fn data_slot_len(&self, slot: usize) -> usize {
        self.topo.data_slots[slot].len as usize
    }

    /// Overwrite the (lane-shared) data behind slot `slot` without
    /// touching the program structure — the batched twin of
    /// [`crate::autodiff::TapeProgram::rebind_data_slot`].  Node slots
    /// broadcast each element to every lane.
    pub fn rebind_data_slot(&mut self, slot: usize, data: &[f64]) {
        let DataSlot { store, start, len } = self.topo.data_slots[slot];
        let (s, l) = (start as usize, len as usize);
        assert_eq!(data.len(), l, "rebind_data_slot: length mismatch");
        match store {
            SlotStore::Coeffs => self.topo.arena_shared[s..s + l].copy_from_slice(data),
            SlotStore::Consts => self.topo.consts[s..s + l].copy_from_slice(data),
            SlotStore::Nodes => {
                let lanes = self.lanes;
                for (j, &id) in self.topo.slot_nodes[s..s + l].iter().enumerate() {
                    let vs = id as usize * lanes;
                    self.values[vs..vs + lanes].fill(data[j]);
                }
            }
        }
    }

    /// Rebind the inputs (input-major, lane-minor: `inputs[k * lanes ..
    /// (k+1) * lanes]` are the lanes of input slot `k`) and run the
    /// lane-minor forward sweep.  Zero allocations, no interpretation.
    pub fn forward(&mut self, inputs: &[f64]) {
        let l = self.lanes;
        assert_eq!(
            inputs.len(),
            self.topo.inputs.len() * l,
            "BatchTapeProgram::forward: input length mismatch"
        );
        for (k, &id) in self.topo.inputs.iter().enumerate() {
            let s = id as usize * l;
            self.values[s..s + l].copy_from_slice(&inputs[k * l..(k + 1) * l]);
        }
        let BTopology {
            ops,
            arena_parents,
            arena_shared,
            comp_kinds,
            consts,
            ..
        } = &self.topo;
        let values = &mut self.values;
        let partials = &mut self.partials;
        let vals = &mut self.vals;
        let acc_a = &mut self.acc_a;
        let acc_b = &mut self.acc_b;
        let mut ci = 0usize;
        for i in 0..ops.len() {
            match ops[i] {
                BOp::Leaf | BOp::Input => {}
                BOp::Add(x, y) => add_sweep(values, i, x, y, l),
                BOp::Sub(x, y) => sub_sweep(values, i, x, y, l),
                BOp::Mul(x, y) => mul_sweep(values, i, x, y, l),
                BOp::Div(x, y) => div_sweep(values, i, x, y, l),
                BOp::Neg(x) => neg_sweep(values, i, x, l),
                BOp::Exp(x) => unary_sweep(values, i, x, l, f64::exp),
                BOp::Ln(x) => unary_sweep(values, i, x, l, f64::ln),
                BOp::Log1p(x) => unary_sweep(values, i, x, l, f64::ln_1p),
                BOp::Sqrt(x) => unary_sweep(values, i, x, l, f64::sqrt),
                BOp::Sigmoid(x) => unary_sweep(values, i, x, l, sigmoid_val),
                BOp::Softplus(x) => unary_sweep(values, i, x, l, softplus_val),
                BOp::Powi(x, n) => unary_sweep(values, i, x, l, |a| a.powi(n)),
                BOp::Scale(x, c) => scale_sweep(values, i, x, l, c),
                BOp::Offset(x, c) => offset_sweep(values, i, x, l, c),
                BOp::Composite { pstart, xstart, .. } => {
                    let kind = comp_kinds[ci];
                    ci += 1;
                    let (src, dst) = values.split_at_mut(i * l);
                    batch_composite_forward(
                        kind,
                        l,
                        pstart as usize,
                        xstart as usize,
                        arena_parents,
                        consts,
                        src,
                        partials,
                        vals,
                        acc_a,
                        acc_b,
                    );
                    dst[..l].copy_from_slice(vals);
                }
                BOp::CompositeShared { pstart, sstart, len } => {
                    debug_assert!(matches!(comp_kinds[ci], CompKind::Affine));
                    ci += 1;
                    let (src, dst) = values.split_at_mut(i * l);
                    for v in vals.iter_mut() {
                        *v = 0.0;
                    }
                    for j in 0..len as usize {
                        let p = arena_shared[sstart as usize + j];
                        let s = arena_parents[pstart as usize + j] as usize * l;
                        for k in 0..l {
                            vals[k] += p * src[s + k];
                        }
                    }
                    dst[..l].copy_from_slice(vals);
                }
            }
        }
    }

    /// Reverse sweep seeded at the output (adjoint 1.0 in every lane),
    /// using the values and composite partials left by the last
    /// [`forward`].
    ///
    /// [`forward`]: BatchTapeProgram::forward
    pub fn backward(&mut self) {
        let l = self.lanes;
        self.adj.iter_mut().for_each(|a| *a = 0.0);
        let o = self.output as usize * l;
        for a in &mut self.adj[o..o + l] {
            *a = 1.0;
        }
        batch_reverse_sweep(
            &self.topo.ops,
            &self.values,
            &self.topo.arena_parents,
            &self.partials,
            &self.topo.arena_shared,
            &mut self.adj,
            l,
        );
    }

    /// Copy the adjoints of the input slots into `grad` (input-major,
    /// lane-minor, same layout as [`forward`]'s `inputs`) after a
    /// [`backward`] sweep.
    ///
    /// [`forward`]: BatchTapeProgram::forward
    /// [`backward`]: BatchTapeProgram::backward
    pub fn input_adjoints(&self, grad: &mut [f64]) {
        let l = self.lanes;
        for (k, &id) in self.topo.inputs.iter().enumerate() {
            let s = id as usize * l;
            grad[k * l..(k + 1) * l].copy_from_slice(&self.adj[s..s + l]);
        }
    }
}

/// Micro-lane width of the frozen forward kernels.  Lanes are swept in
/// fixed-size blocks of `MICRO_LANES`, so the hot inner loop is a
/// bounds-check-free straight-line body over `[f64; MICRO_LANES]`
/// arrays — the shape LLVM reliably turns into packed SIMD — with a
/// scalar remainder loop for ragged widths.  The tiled dispatcher
/// ([`crate::mcmc::TiledBatchPotential`]) rounds its default tile
/// widths to a multiple of this so full tiles never touch the
/// remainder path.
///
/// Bitwise contract: every kernel applies the *same* per-lane scalar
/// function in the same order as a plain `for k in 0..l` sweep, so
/// micro-lane blocking (and the `simd` feature's explicit `std::simd`
/// variants of the exactly-rounded arithmetic ops) cannot change any
/// lane's bits.
pub const MICRO_LANES: usize = 8;

/// Lane-minor unary forward step shared by the frozen sweep: an
/// explicit `MICRO_LANES`-wide unrolled micro-lane kernel plus a
/// scalar remainder.
#[inline]
fn unary_sweep(values: &mut [f64], i: usize, x: u32, l: usize, f: impl Fn(f64) -> f64) {
    let (src, dst) = values.split_at_mut(i * l);
    let xs = x as usize * l;
    let src = &src[xs..xs + l];
    let dst = &mut dst[..l];
    let mut sc = src.chunks_exact(MICRO_LANES);
    let mut dc = dst.chunks_exact_mut(MICRO_LANES);
    for (d, s) in (&mut dc).zip(&mut sc) {
        let s: &[f64; MICRO_LANES] = s.try_into().unwrap();
        let d: &mut [f64; MICRO_LANES] = d.try_into().unwrap();
        for j in 0..MICRO_LANES {
            d[j] = f(s[j]);
        }
    }
    for (d, s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d = f(*s);
    }
}

/// Lane-minor binary forward step shared by the frozen sweep (same
/// micro-lane blocking as [`unary_sweep`]).  With `--features simd`
/// every binary-arith caller dispatches to [`simd_sweep`] instead, so
/// this kernel is only reachable from the stable build.
#[cfg_attr(feature = "simd", allow(dead_code))]
#[inline]
fn binary_sweep(
    values: &mut [f64],
    i: usize,
    x: u32,
    y: u32,
    l: usize,
    f: impl Fn(f64, f64) -> f64,
) {
    let (src, dst) = values.split_at_mut(i * l);
    let (xs, ys) = (x as usize * l, y as usize * l);
    let (xv, yv) = (&src[xs..xs + l], &src[ys..ys + l]);
    let dst = &mut dst[..l];
    let mut xc = xv.chunks_exact(MICRO_LANES);
    let mut yc = yv.chunks_exact(MICRO_LANES);
    let mut dc = dst.chunks_exact_mut(MICRO_LANES);
    for ((d, a), b) in (&mut dc).zip(&mut xc).zip(&mut yc) {
        let a: &[f64; MICRO_LANES] = a.try_into().unwrap();
        let b: &[f64; MICRO_LANES] = b.try_into().unwrap();
        let d: &mut [f64; MICRO_LANES] = d.try_into().unwrap();
        for j in 0..MICRO_LANES {
            d[j] = f(a[j], b[j]);
        }
    }
    for ((d, a), b) in dc
        .into_remainder()
        .iter_mut()
        .zip(xc.remainder())
        .zip(yc.remainder())
    {
        *d = f(*a, *b);
    }
}

/// Explicit `std::simd` micro-lane kernels for the *exactly-rounded*
/// IEEE-754 elementwise ops (`+ - * /`, negation, scale, offset).
/// Because those operations are correctly rounded both as scalars and
/// as vector lanes, the SIMD results are bitwise identical to the
/// scalar sweep — transcendental ops (exp, ln, ...) stay on the
/// unrolled scalar kernels, whose libm calls a vector math library
/// could not reproduce bit-for-bit.  Off by default (`portable_simd`
/// is nightly-only); enable with `--features simd`.
#[cfg(feature = "simd")]
mod simd_sweep {
    use super::MICRO_LANES;
    use std::simd::Simd;

    type F = Simd<f64, MICRO_LANES>;

    #[inline]
    pub(super) fn binary(
        dst: &mut [f64],
        xs: &[f64],
        ys: &[f64],
        op: impl Fn(F, F) -> F,
        scalar: impl Fn(f64, f64) -> f64,
    ) {
        let n = dst.len() / MICRO_LANES * MICRO_LANES;
        let mut k = 0;
        while k < n {
            let a = F::from_slice(&xs[k..k + MICRO_LANES]);
            let b = F::from_slice(&ys[k..k + MICRO_LANES]);
            op(a, b).copy_to_slice(&mut dst[k..k + MICRO_LANES]);
            k += MICRO_LANES;
        }
        for k in n..dst.len() {
            dst[k] = scalar(xs[k], ys[k]);
        }
    }

    #[inline]
    pub(super) fn unary(
        dst: &mut [f64],
        xs: &[f64],
        op: impl Fn(F) -> F,
        scalar: impl Fn(f64) -> f64,
    ) {
        let n = dst.len() / MICRO_LANES * MICRO_LANES;
        let mut k = 0;
        while k < n {
            let a = F::from_slice(&xs[k..k + MICRO_LANES]);
            op(a).copy_to_slice(&mut dst[k..k + MICRO_LANES]);
            k += MICRO_LANES;
        }
        for k in n..dst.len() {
            dst[k] = scalar(xs[k]);
        }
    }
}

/// Generate the dispatching sweep for one exactly-rounded binary
/// arithmetic op: `std::simd` kernel under `--features simd`, the
/// unrolled micro-lane kernel otherwise.  Either way bitwise-equal.
macro_rules! arith_binary_sweep {
    ($name:ident, $op:tt) => {
        #[inline]
        fn $name(values: &mut [f64], i: usize, x: u32, y: u32, l: usize) {
            #[cfg(feature = "simd")]
            {
                let (src, dst) = values.split_at_mut(i * l);
                let (xs, ys) = (x as usize * l, y as usize * l);
                simd_sweep::binary(
                    &mut dst[..l],
                    &src[xs..xs + l],
                    &src[ys..ys + l],
                    |a, b| a $op b,
                    |a, b| a $op b,
                );
            }
            #[cfg(not(feature = "simd"))]
            binary_sweep(values, i, x, y, l, |a, b| a $op b);
        }
    };
}

arith_binary_sweep!(add_sweep, +);
arith_binary_sweep!(sub_sweep, -);
arith_binary_sweep!(mul_sweep, *);
arith_binary_sweep!(div_sweep, /);

/// Negation sweep (exactly rounded: sign-bit flip per lane).
#[inline]
fn neg_sweep(values: &mut [f64], i: usize, x: u32, l: usize) {
    #[cfg(feature = "simd")]
    {
        let (src, dst) = values.split_at_mut(i * l);
        let xs = x as usize * l;
        simd_sweep::unary(&mut dst[..l], &src[xs..xs + l], |a| -a, |a| -a);
    }
    #[cfg(not(feature = "simd"))]
    unary_sweep(values, i, x, l, |a| -a);
}

/// Constant-multiply sweep (`c * x`, exactly rounded per lane).
#[inline]
fn scale_sweep(values: &mut [f64], i: usize, x: u32, l: usize, c: f64) {
    #[cfg(feature = "simd")]
    {
        let (src, dst) = values.split_at_mut(i * l);
        let xs = x as usize * l;
        let cv = std::simd::Simd::splat(c);
        simd_sweep::unary(&mut dst[..l], &src[xs..xs + l], |a| cv * a, |a| c * a);
    }
    #[cfg(not(feature = "simd"))]
    unary_sweep(values, i, x, l, |a| c * a);
}

/// Constant-add sweep (`x + c`, exactly rounded per lane).
#[inline]
fn offset_sweep(values: &mut [f64], i: usize, x: u32, l: usize, c: f64) {
    #[cfg(feature = "simd")]
    {
        let (src, dst) = values.split_at_mut(i * l);
        let xs = x as usize * l;
        let cv = std::simd::Simd::splat(c);
        simd_sweep::unary(&mut dst[..l], &src[xs..xs + l], |a| a + cv, |a| a + c);
    }
    #[cfg(not(feature = "simd"))]
    unary_sweep(values, i, x, l, |a| a + c);
}

/// The batched tape is an [`Alg`] instance: the *same* generic model
/// code that replays on a scalar [`crate::autodiff::Tape`] replays here
/// once for all lanes.  [`Alg::lit`] broadcasts a constant to every
/// lane.  [`Alg::val`] is **not lane-meaningful** with more than one
/// lane — a node holds K independent primals, so returning any single
/// one would silently violate the lane-independence contract for model
/// code that branches on it.  It therefore panics for `lanes > 1`
/// (models that read primal values must use [`BatchTape::lane_values`]
/// / [`BatchTape::value_at`], or fall back to
/// [`crate::mcmc::ScalarLanes`] over the scalar compiler).
impl Alg for BatchTape {
    type V = Var;

    fn lit(&mut self, x: f64) -> Var {
        self.constant(x)
    }
    fn val(&self, v: Var) -> f64 {
        assert!(
            self.lanes == 1,
            "Alg::val on a {}-lane BatchTape: a node has one primal per lane; \
             use lane_values()/value_at() per lane, or sample this model through \
             ScalarLanes instead of the batched compiler",
            self.lanes
        );
        self.value_at(v, 0)
    }
    fn add(&mut self, a: Var, b: Var) -> Var {
        BatchTape::add(self, a, b)
    }
    fn sub(&mut self, a: Var, b: Var) -> Var {
        BatchTape::sub(self, a, b)
    }
    fn mul(&mut self, a: Var, b: Var) -> Var {
        BatchTape::mul(self, a, b)
    }
    fn div(&mut self, a: Var, b: Var) -> Var {
        BatchTape::div(self, a, b)
    }
    fn neg(&mut self, a: Var) -> Var {
        BatchTape::neg(self, a)
    }
    fn exp(&mut self, a: Var) -> Var {
        BatchTape::exp(self, a)
    }
    fn ln(&mut self, a: Var) -> Var {
        BatchTape::ln(self, a)
    }
    fn log1p(&mut self, a: Var) -> Var {
        BatchTape::log1p(self, a)
    }
    fn sqrt(&mut self, a: Var) -> Var {
        BatchTape::sqrt(self, a)
    }
    fn softplus(&mut self, a: Var) -> Var {
        BatchTape::softplus(self, a)
    }
    fn powi(&mut self, a: Var, n: i32) -> Var {
        BatchTape::powi(self, a, n)
    }
    fn scale(&mut self, a: Var, c: f64) -> Var {
        BatchTape::scale(self, a, c)
    }
    fn offset(&mut self, a: Var, c: f64) -> Var {
        BatchTape::offset(self, a, c)
    }
    fn square(&mut self, a: Var) -> Var {
        BatchTape::square(self, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Tape;

    /// A program touching every Alg op (shared with the scalar-tape
    /// bitwise test in `autodiff::tests`).
    fn alg_program<A: Alg>(a: &mut A, x: A::V, y: A::V) -> A::V {
        let s = a.add(x, y);
        let e = a.exp(s);
        let lg = a.log1p(e);
        let q = a.square(x);
        let sc = a.scale(q, -0.5);
        let sp = a.softplus(y);
        let d = a.div(sc, sp);
        let m = a.mul(lg, d);
        let sq = a.sqrt(e);
        let ng = a.neg(sq);
        let o = a.offset(m, 0.25);
        let p = a.powi(y, 3);
        let t = a.sub(o, ng);
        let ln = a.ln(e);
        let u = a.add(t, p);
        a.add(u, ln)
    }

    /// Every lane of the batched tape must agree **bitwise** with a
    /// scalar-tape evaluation of the same program at that lane's
    /// inputs, for both primal values and gradients.
    #[test]
    fn lanes_match_scalar_tape_bitwise() {
        let xs = [0.3, 2.0, -0.7, 1.9];
        let ys = [-1.2, 0.5, 31.5, -0.1];
        let lanes = xs.len();

        let mut bt = BatchTape::new(lanes);
        let bx = bt.input(&xs);
        let by = bt.input(&ys);
        let bout = alg_program(&mut bt, bx, by);
        let bvals = bt.lane_values(bout).to_vec();
        let badj = bt.grad(bout).to_vec();

        for k in 0..lanes {
            let mut t = Tape::new();
            let vx = t.input(xs[k]);
            let vy = t.input(ys[k]);
            let out = alg_program(&mut t, vx, vy);
            assert_eq!(t.value(out), bvals[k], "lane {k} primal");
            let adj = t.grad(out);
            assert_eq!(
                adj[vx.0 as usize],
                badj[bx.0 as usize * lanes + k],
                "lane {k} d/dx"
            );
            assert_eq!(
                adj[vy.0 as usize],
                badj[by.0 as usize * lanes + k],
                "lane {k} d/dy"
            );
        }
    }

    #[test]
    fn sum_and_dot_const_match_scalar_bitwise() {
        let rows = [[0.3, -1.2, 0.9], [1.4, 0.2, -0.5]];
        let coef = [0.5, -1.5, 2.0];
        let lanes = 2;
        let mut bt = BatchTape::new(lanes);
        let vars: Vec<Var> = (0..3)
            .map(|i| bt.input(&[rows[0][i], rows[1][i]]))
            .collect();
        let s = bt.sum(&vars);
        let d = bt.dot_const(&vars, &coef);
        let out = bt.mul(s, d);
        let bvals = bt.lane_values(out).to_vec();
        let badj = bt.grad(out).to_vec();

        for k in 0..lanes {
            let mut t = Tape::new();
            let tv: Vec<Var> = rows[k].iter().map(|&v| t.input(v)).collect();
            let ts = t.sum(&tv);
            let td = t.dot_const(&tv, &coef);
            let tout = t.mul(ts, td);
            assert_eq!(t.value(tout), bvals[k], "lane {k} primal");
            let adj = t.grad(tout);
            for i in 0..3 {
                assert_eq!(
                    adj[tv[i].0 as usize],
                    badj[vars[i].0 as usize * lanes + k],
                    "lane {k} grad[{i}]"
                );
            }
        }
    }

    #[test]
    fn composite_lanes_partials_flow_per_lane() {
        // lane-dependent fused node: value_k = c_k * x_k with partial c_k
        let lanes = 3;
        let xs = [1.5, -2.0, 0.25];
        let cs = [2.0, 3.0, -4.0];
        let mut bt = BatchTape::new(lanes);
        let x = bt.input(&xs);
        let vals: Vec<f64> = (0..lanes).map(|k| cs[k] * xs[k]).collect();
        let node = bt.composite_lanes(&[x], &cs, &vals);
        let adj = bt.grad(node).to_vec();
        for k in 0..lanes {
            assert_eq!(adj[x.0 as usize * lanes + k], cs[k]);
        }
    }

    #[test]
    fn reset_keeps_capacity_watermark() {
        let mut bt = BatchTape::new(4);
        let xs = [0.1, 0.2, 0.3, 0.4];
        let ys = [0.5, -0.6, 0.7, -0.8];
        let x = bt.input(&xs);
        let y = bt.input(&ys);
        let out = alg_program(&mut bt, x, y);
        let _ = bt.grad(out);
        let (nodes, arena) = (bt.node_capacity(), bt.arena_capacity());
        for _ in 0..10 {
            bt.reset();
            let x = bt.input(&xs);
            let y = bt.input(&ys);
            let out = alg_program(&mut bt, x, y);
            let _ = bt.grad(out);
            assert_eq!(bt.node_capacity(), nodes);
            assert_eq!(bt.arena_capacity(), arena);
        }
    }

    /// A freezable batched program hitting the primitives, the shared
    /// composites and every fused observation kernel.
    fn build_freezable(bt: &mut BatchTape, xs: &[f64], ys: &[f64]) -> (Var, Var, Var) {
        let x = bt.input(xs);
        let y = bt.input(ys);
        let base = alg_program(bt, x, y);
        let s = bt.sum(&[x, y, base]);
        let d = bt.dot_const(&[x, y], &[0.75, -0.25]);
        let sg = bt.sigmoid(x);
        let scale = bt.exp(y);
        let n1 = bt.normal_iid_obs(sg, scale, &[0.4, -0.2, 1.1]);
        let n2 = bt.bernoulli_logits_iid_obs(base, &[1.0, 0.0, 1.0]);
        let n3 = bt.normal_plate_obs(&[x, y], scale, &[0.9, -0.7]);
        let n4 = bt.normal_fixed_plate_obs(&[x, y], &[1.5, 0.7], &[0.2, 0.3]);
        let n5 = bt.bernoulli_logits_plate_obs(&[x, y], &[0.0, 1.0]);
        let t1 = bt.add(s, d);
        let t2 = bt.add(t1, n1);
        let t3 = bt.add(t2, n2);
        let t4 = bt.add(t3, n3);
        let t5 = bt.add(t4, n4);
        let out = bt.add(t5, n5);
        (x, y, out)
    }

    /// The frozen batched program must bitwise-equal a batched tape
    /// replay at *different* input points, per lane, for values and
    /// input adjoints.
    #[test]
    fn frozen_batch_program_matches_replay_bitwise() {
        let lanes = 3;
        let xs0 = [0.3, -0.7, 1.1];
        let ys0 = [-1.2, 0.5, 0.02];
        let mut bt = BatchTape::new(lanes);
        let (_x, _y, out) = build_freezable(&mut bt, &xs0, &ys0);
        let mut prog = bt.freeze(out);
        assert_eq!(prog.lanes(), lanes);
        assert_eq!(prog.num_inputs(), 2);
        assert!(!prog.is_empty());

        let points = [
            ([0.3, -0.7, 1.1], [-1.2, 0.5, 0.02]),
            ([1.9, 0.01, -2.4], [0.6, 31.5, -0.3]),
            ([-0.5, 2.2, 0.7], [1.4, -0.9, 0.25]),
        ];
        for (px, py) in &points {
            let mut rt = BatchTape::new(lanes);
            let (rx, ry, rout) = build_freezable(&mut rt, px, py);
            let rvals = rt.lane_values(rout).to_vec();
            let radj = rt.grad(rout).to_vec();

            let mut inputs = Vec::new();
            inputs.extend_from_slice(px);
            inputs.extend_from_slice(py);
            prog.forward(&inputs);
            for k in 0..lanes {
                assert_eq!(
                    prog.output_values()[k].to_bits(),
                    rvals[k].to_bits(),
                    "lane {k} value"
                );
            }
            prog.backward();
            let mut grads = vec![0.0; 2 * lanes];
            prog.input_adjoints(&mut grads);
            for k in 0..lanes {
                assert_eq!(
                    grads[k].to_bits(),
                    radj[rx.0 as usize * lanes + k].to_bits(),
                    "lane {k} d/dx"
                );
                assert_eq!(
                    grads[lanes + k].to_bits(),
                    radj[ry.0 as usize * lanes + k].to_bits(),
                    "lane {k} d/dy"
                );
            }
        }
    }

    /// The scalar twin of [`build_freezable`]: the same op sequence on
    /// a one-lane-equivalent scalar tape.
    fn build_freezable_scalar(t: &mut Tape, xv: f64, yv: f64) -> Var {
        let x = t.input(xv);
        let y = t.input(yv);
        let base = alg_program(t, x, y);
        let s = t.sum(&[x, y, base]);
        let d = t.dot_const(&[x, y], &[0.75, -0.25]);
        let sg = t.sigmoid(x);
        let scale = t.exp(y);
        let n1 = t.normal_iid_obs(sg, scale, &[0.4, -0.2, 1.1]);
        let n2 = t.bernoulli_logits_iid_obs(base, &[1.0, 0.0, 1.0]);
        let n3 = t.normal_plate_obs(&[x, y], scale, &[0.9, -0.7]);
        let n4 = t.normal_fixed_plate_obs(&[x, y], &[1.5, 0.7], &[0.2, 0.3]);
        let n5 = t.bernoulli_logits_plate_obs(&[x, y], &[0.0, 1.0]);
        let t1 = t.add(s, d);
        let t2 = t.add(t1, n1);
        let t3 = t.add(t2, n2);
        let t4 = t.add(t3, n3);
        let t5 = t.add(t4, n4);
        t.add(t5, n5)
    }

    /// Each lane of the frozen batched fused kernels must also match a
    /// *scalar* frozen program at that lane's inputs.
    #[test]
    fn frozen_batch_lanes_match_scalar_frozen() {
        let lanes = 2;
        let xs = [0.4, -1.3];
        let ys = [0.9, 0.15];
        let mut bt = BatchTape::new(lanes);
        let (_, _, bout) = build_freezable(&mut bt, &xs, &ys);
        let mut bprog = bt.freeze(bout);
        let mut inputs = Vec::new();
        inputs.extend_from_slice(&xs);
        inputs.extend_from_slice(&ys);
        bprog.forward(&inputs);
        bprog.backward();
        let mut bgrads = vec![0.0; 2 * lanes];
        bprog.input_adjoints(&mut bgrads);

        for k in 0..lanes {
            let mut t = Tape::new();
            let out = build_freezable_scalar(&mut t, xs[k], ys[k]);
            let mut sprog = t.freeze(out);
            let v = sprog.forward(&[xs[k], ys[k]]);
            assert_eq!(v.to_bits(), bprog.output_values()[k].to_bits(), "lane {k}");
            sprog.backward();
            let mut g = vec![0.0; 2];
            sprog.input_adjoints(&mut g);
            assert_eq!(g[0].to_bits(), bgrads[k].to_bits(), "lane {k} d/dx");
            assert_eq!(g[1].to_bits(), bgrads[lanes + k].to_bits(), "lane {k} d/dy");
        }
    }

    /// Rebound data slots on a frozen batched program must match, per
    /// lane, re-recording against the new data (bitwise) — across the
    /// coefficient, fused-const and node-leaf stores.
    #[test]
    fn rebound_batch_slots_match_rerecord_bitwise() {
        fn build(bt: &mut BatchTape, xs: &[f64], ys: &[f64], coef: &[f64], obs: &[f64], zs: &[f64]) -> (Var, Var, Var) {
            let x = bt.input(xs);
            let y = bt.input(ys);
            bt.begin_data_region();
            let d = bt.dot_const(&[x, y], coef);
            let sg = bt.sigmoid(x);
            let scale = bt.exp(y);
            let n = bt.normal_iid_obs(sg, scale, obs);
            let leaves: Vec<Var> = zs.iter().map(|&z| bt.constant(z)).collect();
            bt.register_data_nodes(&leaves);
            let mut acc = d;
            for &lz in &leaves {
                let m = bt.mul(lz, x);
                acc = bt.add(acc, m);
            }
            bt.end_data_region();
            let out = bt.add(acc, n);
            (x, y, out)
        }
        let lanes = 3;
        let xs = [0.4, -1.3, 0.9];
        let ys = [0.9, 0.15, -0.6];
        let (c0, o0, z0) = ([0.5, -1.5], [0.1, 0.9, -0.4], [1.0, 2.0]);
        let (c1, o1, z1) = ([2.0, 0.25], [-0.6, 0.2, 1.3], [-3.0, 0.5]);

        let mut bt = BatchTape::new(lanes);
        let (_, _, out) = build(&mut bt, &xs, &ys, &c0, &o0, &z0);
        assert_eq!(bt.num_data_slots(), 3);
        let mut prog = bt.freeze(out);
        assert_eq!(prog.num_data_slots(), 3);
        assert_eq!(prog.data_slot_len(1), 3);
        prog.rebind_data_slot(0, &c1);
        prog.rebind_data_slot(1, &o1);
        prog.rebind_data_slot(2, &z1);
        let mut inputs = Vec::new();
        inputs.extend_from_slice(&xs);
        inputs.extend_from_slice(&ys);
        prog.forward(&inputs);
        prog.backward();
        let mut grads = vec![0.0; 2 * lanes];
        prog.input_adjoints(&mut grads);

        let mut rt = BatchTape::new(lanes);
        let (rx, ry, rout) = build(&mut rt, &xs, &ys, &c1, &o1, &z1);
        let rvals = rt.lane_values(rout).to_vec();
        let radj = rt.grad(rout).to_vec();
        for k in 0..lanes {
            assert_eq!(
                prog.output_values()[k].to_bits(),
                rvals[k].to_bits(),
                "lane {k} value"
            );
            assert_eq!(
                grads[k].to_bits(),
                radj[rx.0 as usize * lanes + k].to_bits(),
                "lane {k} d/dx"
            );
            assert_eq!(
                grads[lanes + k].to_bits(),
                radj[ry.0 as usize * lanes + k].to_bits(),
                "lane {k} d/dy"
            );
        }
    }

    #[test]
    #[should_panic(expected = "composite_lanes/composite_shared")]
    fn freeze_rejects_raw_composites() {
        let mut bt = BatchTape::new(2);
        let x = bt.input(&[1.0, 2.0]);
        let node = bt.composite_lanes(&[x], &[3.0, 4.0], &[3.0, 8.0]);
        let _ = bt.freeze(node);
    }
}
