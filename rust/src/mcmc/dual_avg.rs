//! Nesterov dual averaging on log step size (Hoffman-Gelman §3.2),
//! numerically identical to `python/compile/infer/hmc_util.py`.

#[derive(Debug, Clone)]
pub struct DualAverage {
    pub log_step: f64,
    pub log_step_avg: f64,
    grad_sum: f64,
    t: f64,
    mu: f64,
    pub target: f64,
    gamma: f64,
    t0: f64,
    kappa: f64,
}

impl DualAverage {
    pub fn new(step_size: f64, target: f64) -> Self {
        DualAverage {
            log_step: step_size.ln(),
            log_step_avg: 0.0,
            grad_sum: 0.0,
            t: 0.0,
            mu: (10.0 * step_size).ln(),
            target,
            gamma: 0.05,
            t0: 10.0,
            kappa: 0.75,
        }
    }

    pub fn update(&mut self, accept_prob: f64) {
        self.t += 1.0;
        self.grad_sum += self.target - accept_prob;
        self.log_step = self.mu - self.t.sqrt() / self.gamma * self.grad_sum / (self.t + self.t0);
        let eta = self.t.powf(-self.kappa);
        self.log_step_avg = eta * self.log_step + (1.0 - eta) * self.log_step_avg;
    }

    pub fn step_size(&self) -> f64 {
        self.log_step.exp()
    }

    pub fn final_step_size(&self) -> f64 {
        self.log_step_avg.exp()
    }

    /// Restart around a new anchor (window boundary), keeping the target.
    pub fn restart(&mut self, step_size: f64) {
        *self = DualAverage::new(step_size, self.target);
    }

    /// Snapshot the adaptation state for checkpointing:
    /// `(log_step, log_step_avg, grad_sum, t, mu, target)`.  The
    /// gamma/t0/kappa constants are fixed in [`DualAverage::new`] and
    /// need no serialization.
    pub fn state(&self) -> (f64, f64, f64, f64, f64, f64) {
        (
            self.log_step,
            self.log_step_avg,
            self.grad_sum,
            self.t,
            self.mu,
            self.target,
        )
    }

    /// Rebuild from a [`DualAverage::state`] snapshot; subsequent
    /// updates continue bitwise-identically.
    pub fn from_state(
        log_step: f64,
        log_step_avg: f64,
        grad_sum: f64,
        t: f64,
        mu: f64,
        target: f64,
    ) -> Self {
        let mut da = DualAverage::new(1.0, target);
        da.log_step = log_step;
        da.log_step_avg = log_step_avg;
        da.grad_sum = grad_sum;
        da.t = t;
        da.mu = mu;
        da
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_target_accept() {
        // fake world: accept_prob = min(1, exp(-5 (eps - 0.3))): larger
        // steps accept less; fixed point where accept == target.
        let mut da = DualAverage::new(1.0, 0.8);
        for _ in 0..2000 {
            let eps = da.step_size();
            let accept = (-5.0 * (eps - 0.3)).exp().min(1.0);
            da.update(accept);
        }
        let eps = da.final_step_size();
        let accept = (-5.0 * (eps - 0.3)).exp().min(1.0);
        assert!(
            (accept - 0.8).abs() < 0.05,
            "converged accept {accept} at eps {eps}"
        );
    }

    #[test]
    fn shrinks_step_when_rejecting() {
        let mut da = DualAverage::new(1.0, 0.8);
        for _ in 0..50 {
            da.update(0.0);
        }
        assert!(da.step_size() < 0.1);
    }

    #[test]
    fn grows_step_when_accepting() {
        let mut da = DualAverage::new(0.01, 0.8);
        for _ in 0..50 {
            da.update(1.0);
        }
        assert!(da.step_size() > 0.01);
    }
}
