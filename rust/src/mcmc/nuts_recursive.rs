//! Algorithm 1: recursive `BuildTree` (Hoffman & Gelman) with
//! multinomial proposal sampling.
//!
//! This is the host-recursion formulation that JAX cannot trace — the
//! paper's motivation for Algorithm 2.  Run against a PJRT
//! `potential_and_grad` executable it reproduces the *Pyro* cost model:
//! tree logic on the host, one compiled dispatch per leapfrog.

use crate::mcmc::{
    is_u_turn, kinetic, leapfrog, log_add_exp, PhaseState, Potential, Transition,
    MAX_DELTA_ENERGY,
};
use crate::rng::Rng;

/// Subtree summary in integration order (`last` = outermost state
/// reached; the caller's edge was `first`'s predecessor).
pub(crate) struct Subtree {
    pub last: PhaseState,
    pub z_prop: Vec<f64>,
    pub u_prop: f64,
    /// log sum of exp(-H) over leaves
    pub weight: f64,
    pub turning: bool,
    pub diverging: bool,
    pub sum_accept: f64,
    pub n_leapfrog: u32,
}

fn leaf<P: Potential + ?Sized>(
    pot: &mut P,
    edge: &PhaseState,
    eps: f64,
    inv_mass: &[f64],
    energy_0: f64,
) -> Subtree {
    let state = leapfrog(pot, edge, eps, inv_mass);
    let mut energy = state.potential + kinetic(&state.r, inv_mass);
    if energy.is_nan() {
        energy = f64::INFINITY;
    }
    let delta = energy - energy_0;
    Subtree {
        z_prop: state.z.clone(),
        u_prop: state.potential,
        weight: -energy,
        turning: false,
        diverging: delta > MAX_DELTA_ENERGY,
        sum_accept: (-delta).exp().min(1.0),
        n_leapfrog: 1,
        last: state,
    }
}

/// Recursive BuildTree: builds 2^depth leaves from `edge` in the
/// direction of `eps`'s sign, tracking the subtree's first state for
/// internal U-turn checks.  `pub(crate)` so the iterative builder's
/// tests can cross-check both algorithms subtree-by-subtree.
pub(crate) fn build_tree<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    edge: &PhaseState,
    depth: u32,
    eps: f64,
    inv_mass: &[f64],
    energy_0: f64,
) -> (Subtree, PhaseState) {
    if depth == 0 {
        let t = leaf(pot, edge, eps, inv_mass, energy_0);
        let first = t.last.clone();
        return (t, first);
    }
    let (left, first) = build_tree(pot, rng, edge, depth - 1, eps, inv_mass, energy_0);
    if left.turning || left.diverging {
        return (left, first);
    }
    let (right, _right_first) =
        build_tree(pot, rng, &left.last, depth - 1, eps, inv_mass, energy_0);

    let weight = log_add_exp(left.weight, right.weight);
    // uniform multinomial within the subtree
    let take_right = !(right.turning || right.diverging)
        && rng.uniform().ln() < right.weight - weight;
    let (z_prop, u_prop) = if take_right {
        (right.z_prop.clone(), right.u_prop)
    } else {
        (left.z_prop.clone(), left.u_prop)
    };
    let mut turning = right.turning;
    if !right.turning && !right.diverging {
        // U-turn across this (sub)trajectory in integration order
        turning |= if eps > 0.0 {
            is_u_turn(&first.z, &right.last.z, &first.r, &right.last.r, inv_mass)
        } else {
            is_u_turn(&right.last.z, &first.z, &right.last.r, &first.r, inv_mass)
        };
    }
    (
        Subtree {
            last: right.last,
            z_prop,
            u_prop,
            weight,
            turning,
            diverging: left.diverging || right.diverging,
            sum_accept: left.sum_accept + right.sum_accept,
            n_leapfrog: left.n_leapfrog + right.n_leapfrog,
        },
        first,
    )
}

/// One NUTS transition using the recursive tree builder.
pub fn draw<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    max_depth: u32,
) -> Transition {
    let dim = z0.len();
    let mut grad = vec![0.0; dim];
    let potential_0 = pot.value_and_grad(z0, &mut grad);
    let mut r0 = vec![0.0; dim];
    for i in 0..dim {
        r0[i] = rng.normal() / inv_mass[i].sqrt();
    }
    let init = PhaseState {
        z: z0.to_vec(),
        r: r0,
        potential: potential_0,
        grad,
    };
    let energy_0 = init.energy(inv_mass);

    let mut left = init.clone();
    let mut right = init;
    let mut z_prop = z0.to_vec();
    let mut u_prop = potential_0;
    let mut weight = -energy_0;
    let mut sum_accept = 0.0;
    let mut n_leapfrog = 0u32;
    let mut depth = 0u32;
    let mut diverging = false;

    while depth < max_depth {
        let going_right = rng.bernoulli(0.5);
        let eps = if going_right { step_size } else { -step_size };
        let edge = if going_right { &right } else { &left };
        let (sub, _) = build_tree(pot, rng, edge, depth, eps, inv_mass, energy_0);
        sum_accept += sub.sum_accept;
        n_leapfrog += sub.n_leapfrog;
        let complete = !sub.turning && !sub.diverging;
        diverging = sub.diverging;

        if going_right {
            right = sub.last.clone();
        } else {
            left = sub.last.clone();
        }
        if complete {
            // biased progressive sampling across subtrees
            if rng.uniform().ln() < sub.weight - weight {
                z_prop = sub.z_prop;
                u_prop = sub.u_prop;
            }
            weight = log_add_exp(weight, sub.weight);
        } else {
            break;
        }
        depth += 1;
        if is_u_turn(&left.z, &right.z, &left.r, &right.r, inv_mass) {
            break;
        }
    }

    Transition {
        z: z_prop,
        accept_prob: sum_accept / (n_leapfrog.max(1) as f64),
        num_leapfrog: n_leapfrog,
        potential: u_prop,
        diverging,
        depth,
    }
}
