//! Lane-masked batched iterative NUTS: K chains advance through
//! Algorithm 2 (`IterativeBuildTree`) in lock-step, sharing one fused
//! [`BatchPotential`] gradient evaluation per leapfrog — NumPyro's
//! `vmap`-over-`while_loop` trick (paper §3, E7) reproduced natively.
//!
//! # How the lock-step works
//!
//! Every chain (lane) runs the *exact* per-lane logic of
//! [`crate::mcmc::nuts_iterative::draw_in_workspace`], re-expressed as
//! a state machine: between gradient evaluations a lane is either
//! *waiting for its next leapfrog* or *done with the draw*.  The global
//! loop alternates
//!
//! 1. one **batched leapfrog** over all lanes (momentum half-kick,
//!    position drift, one `value_and_grad_batch`, half-kick — all
//!    lane-minor SIMD loops), with finished lanes **masked** by forcing
//!    their step size to `0.0` so their phase-space state is frozen
//!    while the SIMD lanes stay full;
//! 2. scalar per-lane tree bookkeeping (multinomial leaf sampling,
//!    `S[BitCount(n)]` slot updates, U-turn checks, doubling-loop
//!    transitions), during which a lane may finish its subtree, start
//!    the next doubling, or finish the draw and go inactive.
//!
//! Because each lane consumes its own [`Rng`] stream in exactly the
//! order the sequential engine would, and the batched potential is
//! lane-wise bitwise-faithful, **every lane reproduces its sequential
//! chain bit-for-bit** — trajectories, proposals, acceptance
//! statistics, divergences (pinned by this module's tests and by
//! `rust/tests/chain_methods.rs`).  The speedup comes from amortizing
//! the tape-replay dispatch across lanes and from SIMD over the
//! lane-minor arrays; the price is that a draw lasts as many leapfrogs
//! as its *longest* lane (masked lanes still occupy SIMD width).
//!
//! All storage lives in a [`BatchTreeWorkspace`] reused across draws:
//! a steady-state [`draw_batch`] performs **zero heap allocations**
//! (`rust/tests/alloc_free.rs`).

use crate::mcmc::nuts_iterative::{bit_count, candidate_range};
use crate::mcmc::{log_add_exp, BatchPotential, DrawStats, MAX_DELTA_ENERGY};
use crate::obs::Recorder;
use crate::rng::Rng;

/// Per-lane control block of the lock-step state machine.  Mirrors the
/// locals of the sequential `draw_in_workspace` + `build_subtree_ws`.
#[derive(Debug, Clone, Copy, Default)]
struct LaneCtl {
    /// lane finished its draw (masked out of further leapfrogs)
    done: bool,
    /// direction of the current subtree
    going_right: bool,
    /// signed step size of the current subtree
    eps: f64,
    energy_0: f64,
    // -- outer doubling loop --
    depth: u32,
    weight: f64,
    u_prop: f64,
    sum_accept: f64,
    n_leapfrog: u32,
    diverging: bool,
    // -- current subtree --
    n: u32,
    num_leaves: u32,
    sub_weight: f64,
    sub_u_prop: f64,
    sub_sum_accept: f64,
    turning: bool,
    sub_diverging: bool,
    /// lane started the draw with a non-finite energy: no leapfrogs
    /// taken, proposal = start (see [`crate::mcmc::DrawStats::poisoned`])
    poisoned: bool,
}

/// Reusable storage for [`draw_batch`]: the batched phase states
/// (lane-minor `dim x lanes` arrays), the per-lane `S[BitCount(n)]`
/// slot stores, the proposal buffers and the lane control blocks.
/// Create once per (model, chain-count) with the maximum tree depth.
pub struct BatchTreeWorkspace {
    dim: usize,
    lanes: usize,
    max_depth: u32,
    // current integration state (all lane-minor)
    state_z: Vec<f64>,
    state_r: Vec<f64>,
    state_grad: Vec<f64>,
    state_u: Vec<f64>,
    // trajectory endpoints
    left_z: Vec<f64>,
    left_r: Vec<f64>,
    left_grad: Vec<f64>,
    left_u: Vec<f64>,
    right_z: Vec<f64>,
    right_r: Vec<f64>,
    right_grad: Vec<f64>,
    right_u: Vec<f64>,
    /// even-node slot stores: `s_z[(slot * dim + i) * lanes + k]`
    s_z: Vec<f64>,
    s_r: Vec<f64>,
    /// per-subtree multinomial proposal
    sub_z_prop: Vec<f64>,
    /// draw-level proposal (the result of [`draw_batch`])
    z_prop: Vec<f64>,
    /// per-lane masked step size for the current global leapfrog
    eps: Vec<f64>,
    ctl: Vec<LaneCtl>,
    /// flight-recorder handle; observes finished draws only, so it is
    /// bitwise-neutral and allocation-free (see [`crate::obs`])
    recorder: Recorder,
}

impl BatchTreeWorkspace {
    pub fn new(dim: usize, lanes: usize, max_depth: u32) -> BatchTreeWorkspace {
        assert!(lanes > 0, "BatchTreeWorkspace needs at least one lane");
        let slots = max_depth.max(1) as usize;
        let dl = dim * lanes;
        BatchTreeWorkspace {
            dim,
            lanes,
            max_depth,
            state_z: vec![0.0; dl],
            state_r: vec![0.0; dl],
            state_grad: vec![0.0; dl],
            state_u: vec![0.0; lanes],
            left_z: vec![0.0; dl],
            left_r: vec![0.0; dl],
            left_grad: vec![0.0; dl],
            left_u: vec![0.0; lanes],
            right_z: vec![0.0; dl],
            right_r: vec![0.0; dl],
            right_grad: vec![0.0; dl],
            right_u: vec![0.0; lanes],
            s_z: vec![0.0; slots * dl],
            s_r: vec![0.0; slots * dl],
            sub_z_prop: vec![0.0; dl],
            z_prop: vec![0.0; dl],
            eps: vec![0.0; lanes],
            ctl: vec![LaneCtl::default(); lanes],
            recorder: Recorder::global(),
        }
    }

    /// Override the flight recorder captured at construction (tests
    /// inject local registries here; the default is the process
    /// global, which is disabled outside the CLI).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn max_depth(&self) -> u32 {
        self.max_depth
    }

    /// The proposals left behind by the last [`draw_batch`] call,
    /// lane-minor (`z[i * lanes + k]`).
    pub fn proposal(&self) -> &[f64] {
        &self.z_prop
    }

    /// Copy lane `k`'s proposal into `out` (length `dim`).
    pub fn proposal_lane(&self, k: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.dim);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.z_prop[i * self.lanes + k];
        }
    }
}

/// Kinetic energy of lane `k` — same accumulation order as the scalar
/// [`crate::mcmc::kinetic`], so the lane matches bitwise.
#[inline]
fn kinetic_lane(r: &[f64], inv_mass: &[f64], dim: usize, l: usize, k: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..dim {
        let ri = r[i * l + k];
        s += ri * ri * inv_mass[i * l + k];
    }
    0.5 * s
}

/// Lane-`k` U-turn criterion across a chord (same accumulation order
/// as the scalar [`crate::mcmc::is_u_turn`]).
#[inline]
#[allow(clippy::too_many_arguments)]
fn is_u_turn_lane(
    z_left: &[f64],
    z_right: &[f64],
    r_left: &[f64],
    r_right: &[f64],
    inv_mass: &[f64],
    dim: usize,
    l: usize,
    k: usize,
) -> bool {
    let mut dot_l = 0.0;
    let mut dot_r = 0.0;
    for i in 0..dim {
        let idx = i * l + k;
        let dz = z_right[idx] - z_left[idx];
        dot_l += dz * inv_mass[idx] * r_left[idx];
        dot_r += dz * inv_mass[idx] * r_right[idx];
    }
    dot_l <= 0.0 || dot_r <= 0.0
}

/// Begin lane `k`'s next subtree: sample the doubling direction from
/// the lane's own RNG, copy the corresponding trajectory endpoint into
/// the integration state, and reset the subtree accumulators — the
/// per-lane equivalent of the sequential outer-loop prologue plus
/// `build_subtree_ws`'s entry.
fn start_subtree(ws: &mut BatchTreeWorkspace, rngs: &mut [Rng], step_sizes: &[f64], k: usize) {
    let (dim, l) = (ws.dim, ws.lanes);
    let going_right = rngs[k].bernoulli(0.5);
    {
        let c = &mut ws.ctl[k];
        c.going_right = going_right;
        c.eps = if going_right {
            step_sizes[k]
        } else {
            -step_sizes[k]
        };
        c.n = 0;
        c.num_leaves = 1 << c.depth;
        c.sub_weight = f64::NEG_INFINITY;
        c.sub_u_prop = f64::INFINITY;
        c.sub_sum_accept = 0.0;
        c.turning = false;
        c.sub_diverging = false;
    }
    if going_right {
        for i in 0..dim {
            let idx = i * l + k;
            ws.state_z[idx] = ws.right_z[idx];
            ws.state_r[idx] = ws.right_r[idx];
            ws.state_grad[idx] = ws.right_grad[idx];
        }
        ws.state_u[k] = ws.right_u[k];
    } else {
        for i in 0..dim {
            let idx = i * l + k;
            ws.state_z[idx] = ws.left_z[idx];
            ws.state_r[idx] = ws.left_r[idx];
            ws.state_grad[idx] = ws.left_grad[idx];
        }
        ws.state_u[k] = ws.left_u[k];
    }
    // the subtree's multinomial proposal starts at the edge state
    for i in 0..dim {
        ws.sub_z_prop[i * l + k] = ws.state_z[i * l + k];
    }
}

/// Lane `k`'s bookkeeping after a leapfrog landed on its next leaf:
/// multinomial progressive sampling, slot store / U-turn checks, and —
/// when the subtree is complete — the outer doubling-loop transition
/// (biased proposal swap, endpoint update, overall U-turn check, next
/// subtree or draw completion).  Mirrors the sequential engine
/// statement-for-statement per lane, including RNG consumption order.
fn after_leapfrog(
    ws: &mut BatchTreeWorkspace,
    rngs: &mut [Rng],
    step_sizes: &[f64],
    inv_mass: &[f64],
    max_depth: u32,
    k: usize,
) {
    let (dim, l) = (ws.dim, ws.lanes);

    // --- leaf bookkeeping (build_subtree_ws loop body) ---
    let mut energy = ws.state_u[k] + kinetic_lane(&ws.state_r, inv_mass, dim, l, k);
    if energy.is_nan() {
        energy = f64::INFINITY;
    }
    let delta = energy - ws.ctl[k].energy_0;
    ws.ctl[k].sub_diverging = delta > MAX_DELTA_ENERGY;
    ws.ctl[k].sub_sum_accept += (-delta).exp().min(1.0);

    let leaf_w = -energy;
    let new_weight = log_add_exp(ws.ctl[k].sub_weight, leaf_w);
    if rngs[k].uniform().ln() < leaf_w - new_weight {
        for i in 0..dim {
            ws.sub_z_prop[i * l + k] = ws.state_z[i * l + k];
        }
        ws.ctl[k].sub_u_prop = ws.state_u[k];
    }
    ws.ctl[k].sub_weight = new_weight;

    let n = ws.ctl[k].n;
    if n % 2 == 0 {
        let slot = bit_count(n) as usize;
        let base = slot * dim * l;
        for i in 0..dim {
            let idx = i * l + k;
            ws.s_z[base + idx] = ws.state_z[idx];
            ws.s_r[base + idx] = ws.state_r[idx];
        }
    } else {
        let (i_min, i_max) = candidate_range(n);
        for slot in i_min..=i_max {
            let base = (slot as usize) * dim * l;
            let cand_z = &ws.s_z[base..base + dim * l];
            let cand_r = &ws.s_r[base..base + dim * l];
            // candidate precedes `state` in integration order
            let t = if ws.ctl[k].eps > 0.0 {
                is_u_turn_lane(
                    cand_z,
                    &ws.state_z,
                    cand_r,
                    &ws.state_r,
                    inv_mass,
                    dim,
                    l,
                    k,
                )
            } else {
                is_u_turn_lane(
                    &ws.state_z,
                    cand_z,
                    &ws.state_r,
                    cand_r,
                    inv_mass,
                    dim,
                    l,
                    k,
                )
            };
            if t {
                ws.ctl[k].turning = true;
                break;
            }
        }
    }
    ws.ctl[k].n += 1;

    if ws.ctl[k].n < ws.ctl[k].num_leaves && !ws.ctl[k].turning && !ws.ctl[k].sub_diverging {
        return; // subtree continues: lane takes the next global leapfrog
    }

    // --- subtree finished: outer doubling-loop bookkeeping ---
    ws.ctl[k].sum_accept += ws.ctl[k].sub_sum_accept;
    ws.ctl[k].n_leapfrog += ws.ctl[k].n;
    let complete = !ws.ctl[k].turning && !ws.ctl[k].sub_diverging;
    ws.ctl[k].diverging = ws.ctl[k].sub_diverging;

    // trajectory endpoint <- subtree's last state
    if ws.ctl[k].going_right {
        for i in 0..dim {
            let idx = i * l + k;
            ws.right_z[idx] = ws.state_z[idx];
            ws.right_r[idx] = ws.state_r[idx];
            ws.right_grad[idx] = ws.state_grad[idx];
        }
        ws.right_u[k] = ws.state_u[k];
    } else {
        for i in 0..dim {
            let idx = i * l + k;
            ws.left_z[idx] = ws.state_z[idx];
            ws.left_r[idx] = ws.state_r[idx];
            ws.left_grad[idx] = ws.state_grad[idx];
        }
        ws.left_u[k] = ws.state_u[k];
    }

    if complete {
        if rngs[k].uniform().ln() < ws.ctl[k].sub_weight - ws.ctl[k].weight {
            for i in 0..dim {
                ws.z_prop[i * l + k] = ws.sub_z_prop[i * l + k];
            }
            ws.ctl[k].u_prop = ws.ctl[k].sub_u_prop;
        }
        ws.ctl[k].weight = log_add_exp(ws.ctl[k].weight, ws.ctl[k].sub_weight);
    } else {
        ws.ctl[k].done = true;
        return;
    }
    ws.ctl[k].depth += 1;
    if is_u_turn_lane(
        &ws.left_z,
        &ws.right_z,
        &ws.left_r,
        &ws.right_r,
        inv_mass,
        dim,
        l,
        k,
    ) {
        ws.ctl[k].done = true;
        return;
    }
    if ws.ctl[k].depth >= max_depth {
        ws.ctl[k].done = true;
        return;
    }
    start_subtree(ws, rngs, step_sizes, k);
}

/// One NUTS transition for **all lanes at once**, with zero heap
/// allocations: every buffer comes from `ws`, the proposals are left
/// in `ws.z_prop` (read via [`BatchTreeWorkspace::proposal`] /
/// [`BatchTreeWorkspace::proposal_lane`]) and the per-lane statistics
/// are written into `out`.
///
/// Inputs are lane-minor: `z0[i * lanes + k]`, `inv_mass[i * lanes +
/// k]`; `step_sizes[k]` and `rngs[k]` are per-lane.  Each lane's
/// transition is bitwise identical to
/// [`crate::mcmc::nuts_iterative::draw_in_workspace`] run with the same
/// scalar potential, RNG state, step size and inverse mass.
#[allow(clippy::too_many_arguments)]
pub fn draw_batch<BP: BatchPotential + ?Sized>(
    pot: &mut BP,
    rngs: &mut [Rng],
    ws: &mut BatchTreeWorkspace,
    z0: &[f64],
    step_sizes: &[f64],
    inv_mass: &[f64],
    max_depth: u32,
    out: &mut [DrawStats],
) {
    let dim = ws.dim;
    let l = ws.lanes;
    assert_eq!(pot.dim(), dim, "workspace/potential dimension mismatch");
    assert_eq!(pot.lanes(), l, "workspace/potential lane-count mismatch");
    assert_eq!(z0.len(), dim * l, "z0 must be dim x lanes (lane-minor)");
    assert_eq!(step_sizes.len(), l);
    assert_eq!(inv_mass.len(), dim * l);
    assert_eq!(rngs.len(), l);
    assert_eq!(out.len(), l);
    assert!(
        max_depth <= ws.max_depth,
        "workspace sized for max_depth {} < {}",
        ws.max_depth,
        max_depth
    );

    // --- per-lane trajectory initialization at z0 ---
    ws.left_z.copy_from_slice(z0);
    pot.value_and_grad_batch(&ws.left_z, &mut ws.left_u, &mut ws.left_grad);
    for k in 0..l {
        // same per-lane draw order as the sequential engine: momenta
        // coordinate-by-coordinate from this lane's own stream
        for i in 0..dim {
            let idx = i * l + k;
            ws.left_r[idx] = rngs[k].normal() / inv_mass[idx].sqrt();
        }
    }
    ws.right_z.copy_from_slice(&ws.left_z);
    ws.right_r.copy_from_slice(&ws.left_r);
    ws.right_grad.copy_from_slice(&ws.left_grad);
    ws.right_u.copy_from_slice(&ws.left_u);
    ws.z_prop.copy_from_slice(z0);

    for k in 0..l {
        let energy_0 = ws.left_u[k] + kinetic_lane(&ws.left_r, inv_mass, dim, l, k);
        ws.ctl[k] = LaneCtl {
            done: false,
            going_right: false,
            eps: 0.0,
            energy_0,
            depth: 0,
            weight: -energy_0,
            u_prop: ws.left_u[k],
            sum_accept: 0.0,
            n_leapfrog: 0,
            diverging: false,
            n: 0,
            num_leaves: 0,
            sub_weight: f64::NEG_INFINITY,
            sub_u_prop: f64::INFINITY,
            sub_sum_accept: 0.0,
            turning: false,
            sub_diverging: false,
            poisoned: false,
        };
        // Containment: a lane whose starting energy is already
        // non-finite would NaN-poison every delta comparison for its
        // whole trajectory.  Quarantine it immediately: mark it done
        // (its eps mask goes to 0.0, so the batched leapfrogs cannot
        // disturb sibling lanes through it), count a divergence, and
        // leave its proposal at the start position.  RNG consumption
        // matches the sequential poisoned path exactly: momenta only,
        // no direction bit.
        if !energy_0.is_finite() {
            ws.ctl[k].done = true;
            ws.ctl[k].diverging = true;
            ws.ctl[k].poisoned = true;
            ws.ctl[k].u_prop = f64::INFINITY;
        } else if max_depth == 0 {
            ws.ctl[k].done = true;
        } else {
            start_subtree(ws, rngs, step_sizes, k);
        }
    }

    // --- lock-step doubling: batched leapfrogs + per-lane bookkeeping ---
    loop {
        let mut any_active = false;
        for k in 0..l {
            let active = !ws.ctl[k].done;
            // lane mask: a finished lane integrates with eps = 0.0, so
            // its live state is frozen while the SIMD lanes stay full
            ws.eps[k] = if active { ws.ctl[k].eps } else { 0.0 };
            any_active |= active;
        }
        if !any_active {
            break;
        }

        // batched velocity-Verlet step (same arithmetic, same order
        // per lane as `leapfrog_inplace`)
        for i in 0..dim {
            let base = i * l;
            for k in 0..l {
                ws.state_r[base + k] -= 0.5 * ws.eps[k] * ws.state_grad[base + k];
            }
        }
        for i in 0..dim {
            let base = i * l;
            for k in 0..l {
                ws.state_z[base + k] += ws.eps[k] * inv_mass[base + k] * ws.state_r[base + k];
            }
        }
        pot.value_and_grad_batch(&ws.state_z, &mut ws.state_u, &mut ws.state_grad);
        for i in 0..dim {
            let base = i * l;
            for k in 0..l {
                ws.state_r[base + k] -= 0.5 * ws.eps[k] * ws.state_grad[base + k];
            }
        }

        for k in 0..l {
            if !ws.ctl[k].done {
                after_leapfrog(ws, rngs, step_sizes, inv_mass, max_depth, k);
            }
        }
    }

    for (k, o) in out.iter_mut().enumerate() {
        let c = &ws.ctl[k];
        *o = DrawStats {
            accept_prob: c.sum_accept / (c.n_leapfrog.max(1) as f64),
            num_leapfrog: c.n_leapfrog,
            potential: c.u_prop,
            diverging: c.diverging,
            depth: c.depth,
            poisoned: c.poisoned,
        };
    }
    if ws.recorder.enabled() {
        for o in out.iter() {
            ws.recorder.record_draw(
                o.accept_prob,
                o.depth,
                o.num_leapfrog as u64,
                o.diverging,
                o.poisoned,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::nuts_iterative::{draw_in_workspace, TreeWorkspace};
    use crate::mcmc::{Potential, ScalarLanes};

    /// Anisotropic quadratic bowl (same as the nuts_iterative tests):
    /// U-turns arrive within a few doublings.
    #[derive(Clone)]
    struct Bowl;
    impl Potential for Bowl {
        fn dim(&self) -> usize {
            3
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            let scale = [1.0, 4.0, 0.25];
            let mut u = 0.0;
            for i in 0..3 {
                grad[i] = z[i] / scale[i];
                u += 0.5 * z[i] * z[i] / scale[i];
            }
            u
        }
    }

    /// Each lane of the batched engine must reproduce its sequential
    /// counterpart bit-for-bit across chained draws — even with
    /// per-lane step sizes, seeds and mass matrices, so lanes finish
    /// their trajectories at different times and the mask is exercised.
    #[test]
    fn lanes_match_sequential_draws_bitwise() {
        let dim = 3;
        let lanes = 4;
        let max_depth = 8;
        let steps = [0.15, 0.3, 0.08, 0.22];
        let seeds = [11u64, 22, 33, 44];
        let masses: [[f64; 3]; 4] = [
            [1.0, 0.5, 2.0],
            [0.8, 1.1, 0.9],
            [1.0, 1.0, 1.0],
            [2.0, 0.3, 1.4],
        ];
        let z_init = [0.9, -0.4, 0.3];

        // batched run
        let mut pot = ScalarLanes::new(vec![Bowl; lanes]);
        let mut ws = BatchTreeWorkspace::new(dim, lanes, max_depth);
        let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
        let mut z = vec![0.0; dim * lanes];
        let mut inv_mass = vec![0.0; dim * lanes];
        for k in 0..lanes {
            for i in 0..dim {
                z[i * lanes + k] = z_init[i];
                inv_mass[i * lanes + k] = masses[k][i];
            }
        }
        let mut stats = vec![
            DrawStats {
                accept_prob: 0.0,
                num_leapfrog: 0,
                potential: 0.0,
                diverging: false,
                depth: 0,
                poisoned: false,
            };
            lanes
        ];

        // sequential reference, one engine per lane
        let mut seq_pots: Vec<Bowl> = vec![Bowl; lanes];
        let mut seq_ws: Vec<TreeWorkspace> =
            (0..lanes).map(|_| TreeWorkspace::new(dim, max_depth)).collect();
        let mut seq_rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
        let mut seq_z: Vec<Vec<f64>> = (0..lanes).map(|_| z_init.to_vec()).collect();

        for draw in 0..20 {
            draw_batch(
                &mut pot,
                &mut rngs,
                &mut ws,
                &z,
                &steps,
                &inv_mass,
                max_depth,
                &mut stats,
            );
            for k in 0..lanes {
                let st = draw_in_workspace(
                    &mut seq_pots[k],
                    &mut seq_rngs[k],
                    &mut seq_ws[k],
                    &seq_z[k],
                    steps[k],
                    &masses[k],
                    max_depth,
                );
                seq_z[k].copy_from_slice(seq_ws[k].proposal());
                for i in 0..dim {
                    assert_eq!(
                        ws.proposal()[i * lanes + k],
                        seq_z[k][i],
                        "draw {draw} lane {k} z[{i}]"
                    );
                }
                assert_eq!(stats[k].accept_prob, st.accept_prob, "draw {draw} lane {k}");
                assert_eq!(stats[k].num_leapfrog, st.num_leapfrog, "draw {draw} lane {k}");
                assert_eq!(stats[k].potential, st.potential, "draw {draw} lane {k}");
                assert_eq!(stats[k].diverging, st.diverging, "draw {draw} lane {k}");
                assert_eq!(stats[k].depth, st.depth, "draw {draw} lane {k}");
            }
            // chain the draws
            z.copy_from_slice(ws.proposal());
        }
    }

    /// A single lane through the batched engine is just sequential NUTS.
    #[test]
    fn single_lane_matches_sequential() {
        let dim = 3;
        let max_depth = 10;
        let mut pot = ScalarLanes::new(vec![Bowl]);
        let mut ws = BatchTreeWorkspace::new(dim, 1, max_depth);
        let mut rngs = vec![Rng::new(7)];
        let mut z = vec![0.3, -0.8, 1.2];
        let inv_mass = vec![1.0, 0.5, 2.0];
        let mut stats = vec![
            DrawStats {
                accept_prob: 0.0,
                num_leapfrog: 0,
                potential: 0.0,
                diverging: false,
                depth: 0,
                poisoned: false,
            };
            1
        ];

        let mut seq_pot = Bowl;
        let mut seq_ws = TreeWorkspace::new(dim, max_depth);
        let mut seq_rng = Rng::new(7);
        let mut seq_z = z.clone();

        for _ in 0..25 {
            draw_batch(
                &mut pot,
                &mut rngs,
                &mut ws,
                &z,
                &[0.2],
                &inv_mass,
                max_depth,
                &mut stats,
            );
            let st = draw_in_workspace(
                &mut seq_pot,
                &mut seq_rng,
                &mut seq_ws,
                &seq_z,
                0.2,
                &inv_mass,
                max_depth,
            );
            seq_z.copy_from_slice(seq_ws.proposal());
            assert_eq!(ws.proposal(), seq_z.as_slice());
            assert_eq!(stats[0].num_leapfrog, st.num_leapfrog);
            assert_eq!(stats[0].accept_prob, st.accept_prob);
            z.copy_from_slice(ws.proposal());
        }
    }
}
