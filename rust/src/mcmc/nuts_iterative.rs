//! Algorithm 2: `IterativeBuildTree` (the paper's Appendix A), in Rust.
//!
//! Identical trajectory logic to the in-graph JAX implementation
//! (`python/compile/infer/nuts.py`): 2^depth leapfrog steps in a flat
//! loop; even nodes stored at `S[BitCount(n)]`; at odd nodes the U-turn
//! condition is checked against the candidate set C(n) (trailing 1-bits
//! progressively masked), giving O(max_depth) memory.
//!
//! Run against the native autodiff potentials this is the *Stan* cost
//! model (compiled native code, no per-leapfrog dispatch); the contrast
//! with [`super::nuts_recursive`] isolates the iterative-formulation
//! overhead that the paper reports as "insignificant" (E8).
//!
//! All per-draw scratch — the `S[BitCount(n)]` slot arrays, the
//! integration state, the subtree/draw proposal buffers and the
//! trajectory endpoints — lives in a [`TreeWorkspace`] that the caller
//! reuses across draws, so a steady-state draw through
//! [`draw_in_workspace`] performs **zero heap allocations** (the
//! gradient evaluations are allocation-free too once the native
//! potentials' tapes have warmed up).

use crate::mcmc::{
    is_u_turn, kinetic, leapfrog_inplace, log_add_exp, DrawStats, PhaseState, Potential,
    Transition, MAX_DELTA_ENERGY,
};
use crate::obs::{Recorder, SpanKind};
use crate::rng::Rng;

#[inline]
pub fn bit_count(n: u32) -> u32 {
    n.count_ones()
}

#[inline]
pub fn trailing_ones(n: u32) -> u32 {
    (n ^ (n + 1)).count_ones() - 1
}

/// Candidate storage-index range [i_min, i_max] for odd n (Appendix A).
#[inline]
pub fn candidate_range(n: u32) -> (u32, u32) {
    let i_max = bit_count(n - 1);
    let i_min = i_max + 1 - trailing_ones(n);
    (i_min, i_max)
}

/// Reusable per-draw storage for the iterative tree builder.  Create it
/// once per (chain, model) with the target dimension and the *maximum*
/// tree depth you will ever pass to [`draw_in_workspace`].
pub struct TreeWorkspace {
    dim: usize,
    max_depth: u32,
    /// S[i] stores the even node with BitCount == i: positions
    s_z: Vec<f64>,
    /// ... and momenta
    s_r: Vec<f64>,
    /// current integration state (the subtree's `last` after a build)
    state: PhaseState,
    /// proposal within the current subtree
    sub_z_prop: Vec<f64>,
    /// trajectory endpoints for the outer doubling loop
    left: PhaseState,
    right: PhaseState,
    /// draw-level proposal (the result of [`draw_in_workspace`])
    z_prop: Vec<f64>,
    /// flight-recorder handle; observes finished draws only, so it is
    /// bitwise-neutral and allocation-free (see [`crate::obs`])
    recorder: Recorder,
}

impl TreeWorkspace {
    pub fn new(dim: usize, max_depth: u32) -> TreeWorkspace {
        let slots = max_depth.max(1) as usize;
        TreeWorkspace {
            dim,
            max_depth,
            s_z: vec![0.0; slots * dim],
            s_r: vec![0.0; slots * dim],
            state: PhaseState::zeros(dim),
            sub_z_prop: vec![0.0; dim],
            left: PhaseState::zeros(dim),
            right: PhaseState::zeros(dim),
            z_prop: vec![0.0; dim],
            recorder: Recorder::global(),
        }
    }

    /// Override the flight recorder captured at construction (tests
    /// inject local registries here; the default is the process
    /// global, which is disabled outside the CLI).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn max_depth(&self) -> u32 {
        self.max_depth
    }

    /// The proposal left behind by the last [`draw_in_workspace`] call.
    pub fn proposal(&self) -> &[f64] {
        &self.z_prop
    }
}

/// Subtree summary of the iterative builder (proposal lives in
/// `ws.sub_z_prop`, the `last` state in `ws.state`).
#[derive(Debug, Clone, Copy)]
struct SubtreeStats {
    u_prop: f64,
    /// log sum of exp(-H) over leaves
    weight: f64,
    turning: bool,
    diverging: bool,
    sum_accept: f64,
    n_leapfrog: u32,
}

/// Build 2^depth leaves iteratively (Algorithm 2) starting from the
/// edge state the caller placed in `ws.state`, with early exit on
/// U-turn / divergence.  On return `ws.state` is the subtree's last
/// state and `ws.sub_z_prop` its multinomial proposal.
fn build_subtree_ws<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    ws: &mut TreeWorkspace,
    depth: u32,
    eps: f64,
    inv_mass: &[f64],
    energy_0: f64,
) -> SubtreeStats {
    let dim = ws.dim;
    let num_leaves: u32 = 1 << depth;

    ws.sub_z_prop.copy_from_slice(&ws.state.z);
    let mut u_prop = f64::INFINITY;
    let mut weight = f64::NEG_INFINITY;
    let mut sum_accept = 0.0;
    let mut turning = false;
    let mut diverging = false;
    let mut n: u32 = 0;

    while n < num_leaves && !turning && !diverging {
        leapfrog_inplace(pot, &mut ws.state, eps, inv_mass);
        let mut energy = ws.state.potential + kinetic(&ws.state.r, inv_mass);
        if energy.is_nan() {
            energy = f64::INFINITY;
        }
        let delta = energy - energy_0;
        diverging = delta > MAX_DELTA_ENERGY;
        sum_accept += (-delta).exp().min(1.0);

        // multinomial progressive sampling within the subtree
        let leaf_w = -energy;
        let new_weight = log_add_exp(weight, leaf_w);
        if rng.uniform().ln() < leaf_w - new_weight {
            ws.sub_z_prop.copy_from_slice(&ws.state.z);
            u_prop = ws.state.potential;
        }
        weight = new_weight;

        if n % 2 == 0 {
            let i = bit_count(n) as usize;
            ws.s_z[i * dim..(i + 1) * dim].copy_from_slice(&ws.state.z);
            ws.s_r[i * dim..(i + 1) * dim].copy_from_slice(&ws.state.r);
        } else {
            let (i_min, i_max) = candidate_range(n);
            for k in i_min..=i_max {
                let k = k as usize;
                let cand_z = &ws.s_z[k * dim..(k + 1) * dim];
                let cand_r = &ws.s_r[k * dim..(k + 1) * dim];
                // candidate precedes `state` in integration order
                let t = if eps > 0.0 {
                    is_u_turn(cand_z, &ws.state.z, cand_r, &ws.state.r, inv_mass)
                } else {
                    is_u_turn(&ws.state.z, cand_z, &ws.state.r, cand_r, inv_mass)
                };
                if t {
                    turning = true;
                    break;
                }
            }
        }
        n += 1;
    }

    SubtreeStats {
        u_prop,
        weight,
        turning,
        diverging,
        sum_accept,
        n_leapfrog: n,
    }
}

/// One NUTS transition with **zero heap allocations**: every buffer
/// comes from `ws`, and the proposal is left in `ws.z_prop` (read it
/// via [`TreeWorkspace::proposal`]).  The outer doubling loop is the
/// same biased-progressive scheme as the recursive version; only the
/// subtree construction differs.
pub fn draw_in_workspace<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    ws: &mut TreeWorkspace,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    max_depth: u32,
) -> DrawStats {
    let _draw_span = ws.recorder.span(SpanKind::Draw);
    let dim = z0.len();
    assert_eq!(dim, ws.dim, "workspace dimension mismatch");
    assert!(
        max_depth <= ws.max_depth,
        "workspace sized for max_depth {} < {}",
        ws.max_depth,
        max_depth
    );

    ws.left.z.copy_from_slice(z0);
    ws.left.potential = pot.value_and_grad(z0, &mut ws.left.grad);
    for i in 0..dim {
        ws.left.r[i] = rng.normal() / inv_mass[i].sqrt();
    }
    ws.right.copy_from(&ws.left);
    let energy_0 = ws.left.energy(inv_mass);
    let potential_0 = ws.left.potential;

    ws.z_prop.copy_from_slice(z0);
    // Containment: a non-finite *initial* energy would make every
    // `delta = energy - energy_0` comparison NaN below, silently
    // disabling divergence detection for the whole trajectory.  Refuse
    // to integrate: report a poisoned draw (counted divergence, zero
    // leapfrogs, proposal = start) and let the coordinator decide
    // whether to quarantine/restart the chain.
    if !energy_0.is_finite() {
        ws.recorder.record_draw(0.0, 0, 0, true, true);
        return DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: f64::INFINITY,
            diverging: true,
            depth: 0,
            poisoned: true,
        };
    }
    let mut u_prop = potential_0;
    let mut weight = -energy_0;
    let mut sum_accept = 0.0;
    let mut n_leapfrog = 0u32;
    let mut depth = 0u32;
    let mut diverging = false;

    while depth < max_depth {
        let going_right = rng.bernoulli(0.5);
        let eps = if going_right { step_size } else { -step_size };
        if going_right {
            ws.state.copy_from(&ws.right);
        } else {
            ws.state.copy_from(&ws.left);
        }
        let sub = build_subtree_ws(pot, rng, ws, depth, eps, inv_mass, energy_0);
        sum_accept += sub.sum_accept;
        n_leapfrog += sub.n_leapfrog;
        let complete = !sub.turning && !sub.diverging;
        diverging = sub.diverging;

        if going_right {
            ws.right.copy_from(&ws.state);
        } else {
            ws.left.copy_from(&ws.state);
        }
        if complete {
            if rng.uniform().ln() < sub.weight - weight {
                ws.z_prop.copy_from_slice(&ws.sub_z_prop);
                u_prop = sub.u_prop;
            }
            weight = log_add_exp(weight, sub.weight);
        } else {
            break;
        }
        depth += 1;
        if is_u_turn(&ws.left.z, &ws.right.z, &ws.left.r, &ws.right.r, inv_mass) {
            break;
        }
    }

    let accept_prob = sum_accept / (n_leapfrog.max(1) as f64);
    ws.recorder
        .record_draw(accept_prob, depth, n_leapfrog as u64, diverging, false);
    DrawStats {
        accept_prob,
        num_leapfrog: n_leapfrog,
        potential: u_prop,
        diverging,
        depth,
        poisoned: false,
    }
}

/// [`draw_in_workspace`] packaged as a [`Transition`] (one proposal-
/// vector allocation per draw — everything else reuses `ws`).
pub fn draw_with<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    ws: &mut TreeWorkspace,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    max_depth: u32,
) -> Transition {
    let stats = draw_in_workspace(pot, rng, ws, z0, step_size, inv_mass, max_depth);
    Transition {
        z: ws.z_prop.clone(),
        accept_prob: stats.accept_prob,
        num_leapfrog: stats.num_leapfrog,
        potential: stats.potential,
        diverging: stats.diverging,
        depth: stats.depth,
    }
}

/// One NUTS transition with a throwaway workspace (compatibility entry
/// point; persistent callers should hold a [`TreeWorkspace`] and use
/// [`draw_with`] / [`draw_in_workspace`]).
pub fn draw<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    max_depth: u32,
) -> Transition {
    let mut ws = TreeWorkspace::new(z0.len(), max_depth);
    draw_with(pot, rng, &mut ws, z0, step_size, inv_mass, max_depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::nuts_recursive;

    #[test]
    fn bit_helpers_match_paper_example() {
        // n = 11 = (1011)2: C(11) = {(1010)2, (1000)2} = {10, 8}
        assert_eq!(trailing_ones(11), 2);
        let (i_min, i_max) = candidate_range(11);
        // i_max = BitCount(10) = 2, two candidates -> i_min = 1
        assert_eq!((i_min, i_max), (1, 2));
    }

    #[test]
    fn trailing_ones_basics() {
        assert_eq!(trailing_ones(0), 0);
        assert_eq!(trailing_ones(1), 1);
        assert_eq!(trailing_ones(3), 2);
        assert_eq!(trailing_ones(7), 3);
        assert_eq!(trailing_ones(8), 0);
    }

    /// Anisotropic quadratic bowl: U-turns happen within a few doublings.
    struct Bowl;
    impl Potential for Bowl {
        fn dim(&self) -> usize {
            3
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            let scale = [1.0, 4.0, 0.25];
            let mut u = 0.0;
            for i in 0..3 {
                grad[i] = z[i] / scale[i];
                u += 0.5 * z[i] * z[i] / scale[i];
            }
            u
        }
    }

    fn initial_state(pot: &mut Bowl) -> PhaseState {
        let mut grad = vec![0.0; 3];
        let z = vec![0.9, -0.4, 0.3];
        let potential = pot.value_and_grad(&z, &mut grad);
        PhaseState {
            z,
            r: vec![0.7, 0.2, -1.1],
            potential,
            grad,
        }
    }

    /// The iterative subtree builder and the recursive Algorithm-1
    /// builder walk the exact same trajectory: identical last state
    /// (bitwise — same leapfrog arithmetic), leapfrog counts, stopping
    /// flags, and (up to summation order) total weight and accept sums.
    #[test]
    fn iterative_and_recursive_subtrees_trace_identical_trajectories() {
        let inv_mass = [1.0, 0.5, 2.0];
        for &eps in &[0.1, -0.1, 0.25] {
            for depth in 0..=6u32 {
                let mut pot_a = Bowl;
                let mut pot_b = Bowl;
                let edge = initial_state(&mut pot_a);
                let energy_0 = edge.energy(&inv_mass);

                let mut ws = TreeWorkspace::new(3, 8);
                ws.state.copy_from(&edge);
                // separate RNG clones: only the RNG-free fields compare
                let mut rng_a = Rng::new(42);
                let sub_it =
                    build_subtree_ws(&mut pot_a, &mut rng_a, &mut ws, depth, eps, &inv_mass, energy_0);

                let mut rng_b = Rng::new(42);
                let (sub_rec, _first) = nuts_recursive::build_tree(
                    &mut pot_b, &mut rng_b, &edge, depth, eps, &inv_mass, energy_0,
                );

                assert_eq!(sub_it.n_leapfrog, sub_rec.n_leapfrog, "depth {depth} eps {eps}");
                assert_eq!(sub_it.turning, sub_rec.turning, "depth {depth} eps {eps}");
                assert_eq!(sub_it.diverging, sub_rec.diverging, "depth {depth} eps {eps}");
                assert_eq!(ws.state.z, sub_rec.last.z, "depth {depth} eps {eps}");
                assert_eq!(ws.state.r, sub_rec.last.r, "depth {depth} eps {eps}");
                // weights/accept sums differ only by summation order
                assert!(
                    (sub_it.weight - sub_rec.weight).abs() < 1e-9 * (1.0 + sub_rec.weight.abs()),
                    "depth {depth} eps {eps}: {} vs {}",
                    sub_it.weight,
                    sub_rec.weight
                );
                assert!(
                    (sub_it.sum_accept - sub_rec.sum_accept).abs() < 1e-9,
                    "depth {depth} eps {eps}: {} vs {}",
                    sub_it.sum_accept,
                    sub_rec.sum_accept
                );
            }
        }
    }

    /// Workspace reuse must not change anything: a fresh workspace per
    /// draw and one long-lived workspace produce bitwise-equal chains.
    #[test]
    fn workspace_reuse_is_bitwise_deterministic() {
        let inv_mass = [1.0, 0.5, 2.0];
        let mut rng_fresh = Rng::new(7);
        let mut rng_reuse = Rng::new(7);
        let mut pot_a = Bowl;
        let mut pot_b = Bowl;
        let mut ws = TreeWorkspace::new(3, 10);
        let mut z_fresh = vec![0.3, -0.8, 1.2];
        let mut z_reuse = z_fresh.clone();
        for _ in 0..25 {
            let a = draw(&mut pot_a, &mut rng_fresh, &z_fresh, 0.2, &inv_mass, 10);
            let b = draw_with(&mut pot_b, &mut rng_reuse, &mut ws, &z_reuse, 0.2, &inv_mass, 10);
            assert_eq!(a.z, b.z);
            assert_eq!(a.num_leapfrog, b.num_leapfrog);
            assert_eq!(a.accept_prob, b.accept_prob);
            assert_eq!(a.potential, b.potential);
            assert_eq!(a.depth, b.depth);
            z_fresh = a.z;
            z_reuse = b.z;
        }
    }
}
