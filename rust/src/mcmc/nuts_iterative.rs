//! Algorithm 2: `IterativeBuildTree` (the paper's Appendix A), in Rust.
//!
//! Identical trajectory logic to the in-graph JAX implementation
//! (`python/compile/infer/nuts.py`): 2^depth leapfrog steps in a flat
//! loop; even nodes stored at `S[BitCount(n)]`; at odd nodes the U-turn
//! condition is checked against the candidate set C(n) (trailing 1-bits
//! progressively masked), giving O(max_depth) memory.
//!
//! Run against the native autodiff potentials this is the *Stan* cost
//! model (compiled native code, no per-leapfrog dispatch); the contrast
//! with [`super::nuts_recursive`] isolates the iterative-formulation
//! overhead that the paper reports as "insignificant" (E8).

use crate::mcmc::{
    is_u_turn, kinetic, leapfrog, PhaseState, Potential, Transition, MAX_DELTA_ENERGY,
};
use crate::rng::Rng;

use super::nuts_recursive::Subtree;

#[inline]
pub fn bit_count(n: u32) -> u32 {
    n.count_ones()
}

#[inline]
pub fn trailing_ones(n: u32) -> u32 {
    (n ^ (n + 1)).count_ones() - 1
}

/// Candidate storage-index range [i_min, i_max] for odd n (Appendix A).
#[inline]
pub fn candidate_range(n: u32) -> (u32, u32) {
    let i_max = bit_count(n - 1);
    let i_min = i_max + 1 - trailing_ones(n);
    (i_min, i_max)
}

/// Build 2^depth leaves iteratively from `edge` (Algorithm 2), with
/// early exit on U-turn / divergence.
fn build_subtree<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    edge: &PhaseState,
    depth: u32,
    eps: f64,
    inv_mass: &[f64],
    energy_0: f64,
    max_depth: u32,
) -> Subtree {
    let dim = edge.z.len();
    let num_leaves: u32 = 1 << depth;
    // S[i] stores the even node with BitCount == i (positions + momenta)
    let slots = max_depth.max(1) as usize;
    let mut s_z = vec![0.0f64; slots * dim];
    let mut s_r = vec![0.0f64; slots * dim];

    let mut state = edge.clone();
    let mut z_prop: Vec<f64> = edge.z.clone();
    let mut u_prop = f64::INFINITY;
    let mut weight = f64::NEG_INFINITY;
    let mut sum_accept = 0.0;
    let mut turning = false;
    let mut diverging = false;
    let mut n: u32 = 0;

    while n < num_leaves && !turning && !diverging {
        state = leapfrog(pot, &state, eps, inv_mass);
        let mut energy = state.potential + kinetic(&state.r, inv_mass);
        if energy.is_nan() {
            energy = f64::INFINITY;
        }
        let delta = energy - energy_0;
        diverging = delta > MAX_DELTA_ENERGY;
        sum_accept += (-delta).exp().min(1.0);

        // multinomial progressive sampling within the subtree
        let leaf_w = -energy;
        let new_weight = log_add_exp(weight, leaf_w);
        if rng.uniform().ln() < leaf_w - new_weight {
            z_prop.copy_from_slice(&state.z);
            u_prop = state.potential;
        }
        weight = new_weight;

        if n % 2 == 0 {
            let i = bit_count(n) as usize;
            s_z[i * dim..(i + 1) * dim].copy_from_slice(&state.z);
            s_r[i * dim..(i + 1) * dim].copy_from_slice(&state.r);
        } else {
            let (i_min, i_max) = candidate_range(n);
            for k in i_min..=i_max {
                let k = k as usize;
                let cand_z = &s_z[k * dim..(k + 1) * dim];
                let cand_r = &s_r[k * dim..(k + 1) * dim];
                // candidate precedes `state` in integration order
                let t = if eps > 0.0 {
                    is_u_turn(cand_z, &state.z, cand_r, &state.r, inv_mass)
                } else {
                    is_u_turn(&state.z, cand_z, &state.r, cand_r, inv_mass)
                };
                if t {
                    turning = true;
                    break;
                }
            }
        }
        n += 1;
    }

    Subtree {
        last: state,
        z_prop,
        u_prop,
        weight,
        turning,
        diverging,
        sum_accept,
        n_leapfrog: n,
    }
}

fn log_add_exp(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// One NUTS transition using the iterative tree builder.  The outer
/// doubling loop is the same biased-progressive scheme as the recursive
/// version; only the subtree construction differs.
pub fn draw<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    max_depth: u32,
) -> Transition {
    let dim = z0.len();
    let mut grad = vec![0.0; dim];
    let potential_0 = pot.value_and_grad(z0, &mut grad);
    let mut r0 = vec![0.0; dim];
    for i in 0..dim {
        r0[i] = rng.normal() / inv_mass[i].sqrt();
    }
    let init = PhaseState {
        z: z0.to_vec(),
        r: r0,
        potential: potential_0,
        grad,
    };
    let energy_0 = init.energy(inv_mass);

    let mut left = init.clone();
    let mut right = init;
    let mut z_prop = z0.to_vec();
    let mut u_prop = potential_0;
    let mut weight = -energy_0;
    let mut sum_accept = 0.0;
    let mut n_leapfrog = 0u32;
    let mut depth = 0u32;
    let mut diverging = false;

    while depth < max_depth {
        let going_right = rng.bernoulli(0.5);
        let eps = if going_right { step_size } else { -step_size };
        let edge = if going_right { &right } else { &left };
        let sub = build_subtree(
            pot, rng, edge, depth, eps, inv_mass, energy_0, max_depth,
        );
        sum_accept += sub.sum_accept;
        n_leapfrog += sub.n_leapfrog;
        let complete = !sub.turning && !sub.diverging;
        diverging = sub.diverging;

        if going_right {
            right = sub.last.clone();
        } else {
            left = sub.last.clone();
        }
        if complete {
            if rng.uniform().ln() < sub.weight - weight {
                z_prop = sub.z_prop;
                u_prop = sub.u_prop;
            }
            weight = log_add_exp(weight, sub.weight);
        } else {
            break;
        }
        depth += 1;
        if is_u_turn(&left.z, &right.z, &left.r, &right.r, inv_mass) {
            break;
        }
    }

    Transition {
        z: z_prop,
        accept_prob: sum_accept / (n_leapfrog.max(1) as f64),
        num_leapfrog: n_leapfrog,
        potential: u_prop,
        diverging,
        depth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_helpers_match_paper_example() {
        // n = 11 = (1011)2: C(11) = {(1010)2, (1000)2} = {10, 8}
        assert_eq!(trailing_ones(11), 2);
        let (i_min, i_max) = candidate_range(11);
        // i_max = BitCount(10) = 2, two candidates -> i_min = 1
        assert_eq!((i_min, i_max), (1, 2));
    }

    #[test]
    fn trailing_ones_basics() {
        assert_eq!(trailing_ones(0), 0);
        assert_eq!(trailing_ones(1), 1);
        assert_eq!(trailing_ones(3), 2);
        assert_eq!(trailing_ones(7), 3);
        assert_eq!(trailing_ones(8), 0);
    }
}
