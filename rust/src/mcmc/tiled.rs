//! Tile-per-thread dispatch over independent lane tiles: the outer
//! level of the two-level massive-lane engine (ROADMAP open item 2).
//!
//! The lane-minor layout (`values[node * lanes + k]`) interleaves *all*
//! K lanes at every tape node, so a single K-wide
//! [`crate::autodiff::BatchTapeProgram`] cannot hand a worker thread a
//! contiguous sub-range of its storage.  Instead a
//! [`TiledBatchPotential`] owns one narrow [`BatchPotential`] per
//! **tile** — each a complete batched program of `tile` lanes in its
//! own lane-minor arrays — and evaluates them either inline (one tile
//! after another, the zero-allocation path) or tile-per-thread on
//! scoped threads (the same chunked-`spawn` idiom as
//! [`crate::coordinator::ParallelChainRunner`]):
//!
//! ```text
//!   K = 1024 lanes, tile = 128, 8 worker threads
//!
//!   z  (dim x 1024, lane-minor)
//!   │ gather               ┌ thread 0: tile 0  [lanes    0..128 ] ┐
//!   ├──────────────────────┤ thread 1: tile 1  [lanes  128..256 ] │
//!   │   per-tile z/u/grad  │   ...        each tile sweeps its    │
//!   │   (dim x 128 each)   │   micro-lanes 8 wide (MICRO_LANES)   │
//!   ├──────────────────────┤ thread 7: tile 7  [lanes  896..1024] ┘
//!   │ scatter
//!   u (1024), grad (dim x 1024, lane-minor)
//! ```
//!
//! # Bitwise contract
//!
//! Lanes are mutually independent in every [`BatchPotential`]
//! implementation (that is the trait's documented contract), so
//! evaluating lane `k` inside a narrow tile performs *exactly* the
//! per-lane operations, in the same order, as evaluating it inside one
//! K-wide program — which is itself bitwise-identical to the scalar
//! tape.  Tiling therefore extends the PR-3/PR-4 contract chain by one
//! more provably-equal link:
//!
//! ```text
//!   scalar Tape == BatchTape == BatchTapeProgram == TiledBatchPotential
//! ```
//!
//! for every K, tile width and thread count — pinned by the property
//! layer in `rust/tests/lane_scaling.rs`.
//!
//! # Allocation discipline
//!
//! All gather/scatter staging buffers are preallocated in
//! [`TiledBatchPotential::new`].  With `threads == 1` an evaluation
//! performs **zero** heap allocations (`rust/tests/alloc_free.rs` pins
//! this at K=128 and K=512); the threaded path pays only
//! `std::thread::scope`'s per-call spawn cost, amortized across the
//! whole lane sweep.

use crate::autodiff::MICRO_LANES;
use crate::mcmc::BatchPotential;
use crate::obs::{Recorder, SpanKind};

/// Split `lanes` into tile widths of at most `tile` lanes each: as
/// many full tiles as fit, plus one ragged remainder tile.
///
/// ```
/// use fugue::mcmc::tile_partition;
/// assert_eq!(tile_partition(1024, 128), vec![128; 8]);
/// assert_eq!(tile_partition(20, 8), vec![8, 8, 4]);
/// assert_eq!(tile_partition(3, 8), vec![3]);
/// ```
pub fn tile_partition(lanes: usize, tile: usize) -> Vec<usize> {
    assert!(lanes > 0, "tile_partition: need at least one lane");
    assert!(tile > 0, "tile_partition: tile width must be positive");
    let mut widths = Vec::with_capacity(lanes.div_ceil(tile));
    let mut rem = lanes;
    while rem > 0 {
        let w = rem.min(tile);
        widths.push(w);
        rem -= w;
    }
    widths
}

/// Default tile width for `lanes` lanes on `threads` workers: balance
/// the lanes across workers, then round up to a multiple of
/// [`MICRO_LANES`] so full tiles never enter the micro-kernels' scalar
/// remainder loop.
///
/// ```
/// use fugue::mcmc::auto_tile_width;
/// assert_eq!(auto_tile_width(1024, 8), 128);
/// assert_eq!(auto_tile_width(100, 8), 16);   // 13 → rounded up to 16
/// assert_eq!(auto_tile_width(4, 8), 4);      // never wider than K
/// ```
pub fn auto_tile_width(lanes: usize, threads: usize) -> usize {
    assert!(lanes > 0, "auto_tile_width: need at least one lane");
    let per = lanes.div_ceil(threads.max(1));
    (per.div_ceil(MICRO_LANES) * MICRO_LANES).min(lanes)
}

/// A [`BatchPotential`] spanning `K = Σ tiles[t].lanes()` lanes by
/// dispatching over per-tile batch potentials (see the module docs for
/// the layout diagram and the bitwise contract).
pub struct TiledBatchPotential<BP: BatchPotential + Send> {
    tiles: Vec<BP>,
    /// first global lane of each tile
    starts: Vec<usize>,
    // per-tile staging buffers, preallocated (lane-minor per tile)
    tile_z: Vec<Vec<f64>>,
    tile_u: Vec<Vec<f64>>,
    tile_g: Vec<Vec<f64>>,
    dim: usize,
    lanes: usize,
    max_threads: usize,
    evals: u64,
    /// flight-recorder handle; counts evals/gathers/scatters and times
    /// the whole batched evaluation (see [`crate::obs`])
    recorder: Recorder,
}

impl<BP: BatchPotential + Send> TiledBatchPotential<BP> {
    /// Assemble a tiled potential from per-tile batch potentials (all
    /// of the same dimension; widths may differ).  Worker count
    /// defaults to the machine's available parallelism, capped by the
    /// tile count.
    pub fn new(tiles: Vec<BP>) -> TiledBatchPotential<BP> {
        assert!(
            !tiles.is_empty(),
            "TiledBatchPotential: need at least one tile"
        );
        let dim = tiles[0].dim();
        let mut starts = Vec::with_capacity(tiles.len());
        let mut lanes = 0usize;
        for t in &tiles {
            assert_eq!(
                t.dim(),
                dim,
                "TiledBatchPotential: all tiles must share one dimension"
            );
            assert!(
                t.lanes() > 0,
                "TiledBatchPotential: every tile needs at least one lane"
            );
            starts.push(lanes);
            lanes += t.lanes();
        }
        let tile_z: Vec<Vec<f64>> = tiles.iter().map(|t| vec![0.0; dim * t.lanes()]).collect();
        let tile_u: Vec<Vec<f64>> = tiles.iter().map(|t| vec![0.0; t.lanes()]).collect();
        let tile_g = tile_z.clone();
        let max_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        TiledBatchPotential {
            tiles,
            starts,
            tile_z,
            tile_u,
            tile_g,
            dim,
            lanes,
            max_threads,
            evals: 0,
            recorder: Recorder::global(),
        }
    }

    /// Override the flight recorder captured at construction (tests
    /// inject local registries here; the default is the process
    /// global, which is disabled outside the CLI).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    /// Cap the worker-thread count (builder form).  `1` forces the
    /// inline zero-allocation path.
    pub fn with_threads(mut self, threads: usize) -> TiledBatchPotential<BP> {
        self.set_threads(threads);
        self
    }

    /// Cap the worker-thread count.  `1` forces the inline
    /// zero-allocation path.
    pub fn set_threads(&mut self, threads: usize) {
        self.max_threads = threads.max(1);
    }

    /// Number of lane tiles.
    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Lane widths of the tiles, in lane order.
    pub fn tile_widths(&self) -> Vec<usize> {
        self.tiles.iter().map(|t| t.lanes()).collect()
    }

    /// Worker threads an evaluation will actually use.
    pub fn threads(&self) -> usize {
        self.max_threads.min(self.tiles.len()).max(1)
    }

    /// Shared access to the per-tile potentials (lane order) — for
    /// read-only cross-cutting queries such as aggregating the
    /// optimizing compiler's plan statistics.
    pub fn tiles(&self) -> &[BP] {
        &self.tiles
    }

    /// Mutable access to the per-tile potentials (lane order) — the
    /// hook that lets cross-cutting operations (e.g. the subsample
    /// minibatch rebind or the `set_optimized` switch in
    /// [`crate::compile::batch_potential`]) fan out over every tile's
    /// own program.
    pub fn tiles_mut(&mut self) -> &mut [BP] {
        &mut self.tiles
    }
}

/// Copy tile `t`'s lanes out of a lane-minor K-wide array into the
/// tile's own lane-minor staging buffer.
#[inline]
fn gather_tile(z: &[f64], tz: &mut [f64], dim: usize, lanes: usize, start: usize, tl: usize) {
    for i in 0..dim {
        tz[i * tl..(i + 1) * tl].copy_from_slice(&z[i * lanes + start..i * lanes + start + tl]);
    }
}

impl<BP: BatchPotential + Send> BatchPotential for TiledBatchPotential<BP> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn lanes(&self) -> usize {
        self.lanes
    }

    fn value_and_grad_batch(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]) {
        let (dim, l) = (self.dim, self.lanes);
        assert_eq!(z.len(), dim * l, "z must be dim x lanes (lane-minor)");
        assert_eq!(u.len(), l);
        assert_eq!(grad.len(), dim * l);
        self.evals += 1;
        let _eval_span = self.recorder.span(SpanKind::TileEval);
        self.recorder.record_tile_eval(self.tiles.len() as u64);

        let threads = self.threads();
        if threads == 1 {
            // inline path: gather + evaluate each tile in turn; no
            // allocation, no synchronization
            for t in 0..self.tiles.len() {
                let tl = self.tiles[t].lanes();
                gather_tile(z, &mut self.tile_z[t], dim, l, self.starts[t], tl);
                self.tiles[t].value_and_grad_batch(
                    &self.tile_z[t],
                    &mut self.tile_u[t],
                    &mut self.tile_g[t],
                );
            }
        } else {
            // tile-per-thread: chunk the tiles (and their staging
            // buffers) across scoped workers — the ParallelChainRunner
            // idiom.  Workers read the shared `z` and write only their
            // own tiles' buffers; the lane-interleaved scatter into
            // `u`/`grad` happens serially below.
            let per = self.tiles.len().div_ceil(threads);
            let starts = &self.starts;
            std::thread::scope(|scope| {
                for ((((tiles, tzs), tus), tgs), sts) in self
                    .tiles
                    .chunks_mut(per)
                    .zip(self.tile_z.chunks_mut(per))
                    .zip(self.tile_u.chunks_mut(per))
                    .zip(self.tile_g.chunks_mut(per))
                    .zip(starts.chunks(per))
                {
                    scope.spawn(move || {
                        for ((((bp, tz), tu), tg), &s) in tiles
                            .iter_mut()
                            .zip(tzs.iter_mut())
                            .zip(tus.iter_mut())
                            .zip(tgs.iter_mut())
                            .zip(sts)
                        {
                            let tl = bp.lanes();
                            gather_tile(z, tz, dim, l, s, tl);
                            bp.value_and_grad_batch(tz, tu, tg);
                        }
                    });
                }
            });
        }

        // scatter: per-lane values are contiguous per tile in `u`, but
        // lane-minor-interleaved across tiles in `grad`
        for t in 0..self.tiles.len() {
            let (s, tl) = (self.starts[t], self.tiles[t].lanes());
            u[s..s + tl].copy_from_slice(&self.tile_u[t]);
            for i in 0..dim {
                grad[i * l + s..i * l + s + tl]
                    .copy_from_slice(&self.tile_g[t][i * tl..(i + 1) * tl]);
            }
        }
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::{Potential, ScalarLanes};

    /// Small anisotropic quadratic, distinct per coordinate.
    #[derive(Clone)]
    struct Bowl;
    impl Potential for Bowl {
        fn dim(&self) -> usize {
            3
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            let scale = [1.0, 4.0, 0.25];
            let mut u = 0.0;
            for i in 0..3 {
                grad[i] = z[i] / scale[i];
                u += 0.5 * z[i] * z[i] / scale[i];
            }
            u
        }
    }

    fn lane_minor_inputs(dim: usize, lanes: usize) -> Vec<f64> {
        (0..dim * lanes)
            .map(|j| ((j * 37 + 11) % 101) as f64 * 0.03 - 1.2)
            .collect()
    }

    #[test]
    fn partition_and_auto_width() {
        assert_eq!(tile_partition(7, 3), vec![3, 3, 1]);
        assert_eq!(tile_partition(8, 8), vec![8]);
        assert_eq!(auto_tile_width(64, 4), 16);
        assert_eq!(auto_tile_width(65, 4), 24); // 17 → next multiple of 8
        assert_eq!(auto_tile_width(5, 64), 5);
    }

    /// Degenerate-case audit (K < MICRO_LANES, K = 1, K around the
    /// micro-lane and typical tile boundaries): pin the exact
    /// partitions so neither helper can regress into a panic, an empty
    /// tile, or a lost lane.
    #[test]
    fn partition_pins_for_degenerate_lane_counts() {
        use crate::autodiff::MICRO_LANES;
        assert_eq!(MICRO_LANES, 8, "pins below assume 8-wide micro-lanes");

        // auto width at 8 worker threads (the common CI shape)
        assert_eq!(auto_tile_width(1, 8), 1);
        assert_eq!(auto_tile_width(7, 8), 7); // never wider than K
        assert_eq!(auto_tile_width(8, 8), 8);
        assert_eq!(auto_tile_width(9, 8), 8);
        assert_eq!(auto_tile_width(63, 8), 8);
        assert_eq!(auto_tile_width(64, 8), 8);
        assert_eq!(auto_tile_width(65, 8), 16); // 9 → next multiple of 8

        // partitions at that auto width
        assert_eq!(tile_partition(1, auto_tile_width(1, 8)), vec![1]);
        assert_eq!(tile_partition(7, auto_tile_width(7, 8)), vec![7]);
        assert_eq!(tile_partition(8, auto_tile_width(8, 8)), vec![8]);
        assert_eq!(tile_partition(9, auto_tile_width(9, 8)), vec![8, 1]);
        assert_eq!(
            tile_partition(63, auto_tile_width(63, 8)),
            vec![8, 8, 8, 8, 8, 8, 8, 7]
        );
        assert_eq!(tile_partition(64, auto_tile_width(64, 8)), vec![8; 8]);
        assert_eq!(
            tile_partition(65, auto_tile_width(65, 8)),
            vec![16, 16, 16, 16, 1]
        );

        // threads > num_tiles: the worker count clamps to the tile
        // count instead of spawning idle threads
        let tiles: Vec<ScalarLanes<Bowl>> = tile_partition(7, 8)
            .into_iter()
            .map(|w| ScalarLanes::new(vec![Bowl; w]))
            .collect();
        let pot = TiledBatchPotential::new(tiles).with_threads(64);
        assert_eq!(pot.num_tiles(), 1);
        assert_eq!(pot.threads(), 1);

        // single worker: one tile spanning all K, no rounding overflow
        assert_eq!(auto_tile_width(65, 1), 65);
        assert_eq!(tile_partition(65, 65), vec![65]);

        // invariants across the audit range: total preserved, no empty
        // tiles, every non-final tile full
        for k in [1usize, 7, 8, 9, 63, 64, 65] {
            for threads in [1usize, 2, 8, 64] {
                let w = auto_tile_width(k, threads);
                let parts = tile_partition(k, w);
                assert_eq!(parts.iter().sum::<usize>(), k, "K={k} threads={threads}");
                assert!(parts.iter().all(|&p| p > 0), "empty tile at K={k}");
                assert!(
                    parts[..parts.len() - 1].iter().all(|&p| p == w),
                    "non-final ragged tile at K={k} threads={threads}"
                );
            }
        }
    }

    /// Every (tile width, thread count) configuration is bitwise-equal
    /// to one wide untiled potential.
    #[test]
    fn tiled_matches_untiled_bitwise() {
        let dim = 3;
        let lanes = 29; // ragged on purpose
        let z = lane_minor_inputs(dim, lanes);
        let mut u_ref = vec![0.0; lanes];
        let mut g_ref = vec![0.0; dim * lanes];
        let mut wide = ScalarLanes::new(vec![Bowl; lanes]);
        wide.value_and_grad_batch(&z, &mut u_ref, &mut g_ref);

        for tile in [1usize, 4, 7, 8, 16, 29] {
            for threads in [1usize, 2, 4] {
                let tiles: Vec<ScalarLanes<Bowl>> = tile_partition(lanes, tile)
                    .into_iter()
                    .map(|w| ScalarLanes::new(vec![Bowl; w]))
                    .collect();
                let mut pot = TiledBatchPotential::new(tiles).with_threads(threads);
                assert_eq!(pot.lanes(), lanes);
                let mut u = vec![0.0; lanes];
                let mut g = vec![0.0; dim * lanes];
                pot.value_and_grad_batch(&z, &mut u, &mut g);
                for k in 0..lanes {
                    assert_eq!(
                        u[k].to_bits(),
                        u_ref[k].to_bits(),
                        "u lane {k} tile {tile} threads {threads}"
                    );
                }
                for j in 0..dim * lanes {
                    assert_eq!(
                        g[j].to_bits(),
                        g_ref[j].to_bits(),
                        "grad slot {j} tile {tile} threads {threads}"
                    );
                }
                assert_eq!(pot.num_evals(), 1);
            }
        }
    }
}
