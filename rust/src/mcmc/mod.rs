//! Pure-Rust NUTS/HMC over a [`Potential`] trait.
//!
//! Two tree-building strategies, mirroring the paper's Figure 4:
//!
//! * [`nuts_recursive`] — Algorithm 1 (Hoffman-Gelman `BuildTree`): the
//!   host-recursion formulation that *cannot* be JIT-traced; paired with
//!   a PJRT `potential_and_grad` executable it reproduces the **Pyro
//!   architecture** (one compiled-callable dispatch per leapfrog).
//! * [`nuts_iterative`] — Algorithm 2 (`IterativeBuildTree`): the
//!   paper's O(log N)-memory iterative formulation, bit-for-bit the same
//!   logic the compiled artifact runs in-graph.  Paired with the native
//!   autodiff models it reproduces the **Stan architecture**.
//!
//! Both produce identical U-turn checks (property-tested against the
//! index-level oracle) and identical statistical behaviour.

pub mod batch_nuts;
pub mod dual_avg;
pub mod hmc;
pub mod nuts_iterative;
pub mod nuts_recursive;
pub mod tiled;
pub mod welford;

pub use batch_nuts::BatchTreeWorkspace;
pub use dual_avg::DualAverage;
pub use hmc::HmcWorkspace;
pub use tiled::{auto_tile_width, tile_partition, TiledBatchPotential};
pub use welford::Welford;

/// A differentiable potential energy U(z) = -log p(z, data).
///
/// Implemented by the hand-fused benchmark models in [`crate::models`],
/// the PJRT-dispatched artifact potential, and — for *arbitrary*
/// effect-handler programs — by [`crate::compile::CompiledModel`],
/// which derives U and ∇U from `sample`/`observe` source via the
/// reusable autodiff tape.
pub trait Potential {
    fn dim(&self) -> usize;

    /// Evaluate U and write dU/dz into `grad`.
    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64;

    /// Number of potential evaluations so far (dispatch accounting for
    /// the benchmark harness).
    fn num_evals(&self) -> u64 {
        0
    }
}

impl Potential for Box<dyn Potential> {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        (**self).value_and_grad(z, grad)
    }

    fn num_evals(&self) -> u64 {
        (**self).num_evals()
    }
}

/// A differentiable potential evaluated over `lanes` independent
/// chains in one call — the gradient interface of the **vectorized
/// chain engine** ([`batch_nuts`]).
///
/// All batched buffers use the *lane-minor* layout: `z[i * lanes + k]`
/// is coordinate `i` of lane (chain) `k`, so each coordinate's lanes
/// are contiguous and every lane-wise inner loop autovectorizes.
///
/// Implemented by [`crate::compile::BatchedCompiledModel`] (one fused
/// multi-lane tape replay per call — the fast path) and by
/// [`ScalarLanes`] (a lane-by-lane adapter over any scalar
/// [`Potential`]).  `ScalarLanes` is not wired in automatically —
/// callers that cannot use the batched compiler (e.g. a model that
/// reads primal values via `ProbCtx::val`) compose it themselves:
/// `run_chains_vectorized(&mut ScalarLanes::new(pots), ...)`.
///
/// **Lane-independence contract:** lane `k` of the outputs must be a
/// pure function of lane `k` of `z` — bitwise identical to what a
/// scalar evaluation at that lane's coordinates would produce.  The
/// batched NUTS engine relies on this to make each vectorized chain
/// reproduce its sequential counterpart exactly.
pub trait BatchPotential {
    fn dim(&self) -> usize;

    /// Number of chains evaluated per call.
    fn lanes(&self) -> usize;

    /// Evaluate `U` per lane (into `u`, length `lanes`) and `dU/dz`
    /// per lane (into `grad`, `dim * lanes` lane-minor).
    fn value_and_grad_batch(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]);

    /// Batched evaluations so far (dispatch accounting).
    fn num_evals(&self) -> u64 {
        0
    }
}

/// Lane-by-lane [`BatchPotential`] over `lanes` copies of a scalar
/// [`Potential`]: no SIMD benefit, but bitwise-faithful per lane by
/// construction.  The generality fallback of the vectorized engine —
/// and the reference implementation its tests compare against.
pub struct ScalarLanes<P: Potential> {
    pots: Vec<P>,
    z_lane: Vec<f64>,
    g_lane: Vec<f64>,
    evals: u64,
}

impl<P: Potential> ScalarLanes<P> {
    /// Build from one scalar potential per lane (all must share `dim`).
    pub fn new(pots: Vec<P>) -> ScalarLanes<P> {
        assert!(!pots.is_empty(), "ScalarLanes needs at least one lane");
        let dim = pots[0].dim();
        assert!(
            pots.iter().all(|p| p.dim() == dim),
            "ScalarLanes: potentials disagree on dimension"
        );
        ScalarLanes {
            pots,
            z_lane: vec![0.0; dim],
            g_lane: vec![0.0; dim],
            evals: 0,
        }
    }
}

impl<P: Potential> BatchPotential for ScalarLanes<P> {
    fn dim(&self) -> usize {
        self.pots[0].dim()
    }

    fn lanes(&self) -> usize {
        self.pots.len()
    }

    fn value_and_grad_batch(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]) {
        self.evals += 1;
        let dim = self.pots[0].dim();
        let l = self.pots.len();
        debug_assert_eq!(z.len(), dim * l);
        for (k, pot) in self.pots.iter_mut().enumerate() {
            for i in 0..dim {
                self.z_lane[i] = z[i * l + k];
            }
            u[k] = pot.value_and_grad(&self.z_lane, &mut self.g_lane);
            for i in 0..dim {
                grad[i * l + k] = self.g_lane[i];
            }
        }
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

/// `ln(e^a + e^b)`, the progressive-sampling weight merge shared by
/// all three tree builders ([`nuts_iterative`], [`nuts_recursive`],
/// [`batch_nuts`]) — one definition so the engines agree bitwise.
#[inline]
pub(crate) fn log_add_exp(a: f64, b: f64) -> f64 {
    let m = a.max(b);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Position + momentum + cached potential/gradient.
#[derive(Debug, Clone)]
pub struct PhaseState {
    pub z: Vec<f64>,
    pub r: Vec<f64>,
    pub potential: f64,
    pub grad: Vec<f64>,
}

impl PhaseState {
    /// Zero-initialized state of dimension `dim` (workspace slot).
    pub fn zeros(dim: usize) -> PhaseState {
        PhaseState {
            z: vec![0.0; dim],
            r: vec![0.0; dim],
            potential: 0.0,
            grad: vec![0.0; dim],
        }
    }

    /// Allocation-free copy (the derived `clone_from` would reallocate).
    pub fn copy_from(&mut self, other: &PhaseState) {
        self.z.copy_from_slice(&other.z);
        self.r.copy_from_slice(&other.r);
        self.grad.copy_from_slice(&other.grad);
        self.potential = other.potential;
    }

    pub fn energy(&self, inv_mass: &[f64]) -> f64 {
        self.potential + kinetic(&self.r, inv_mass)
    }
}

pub fn kinetic(r: &[f64], inv_mass: &[f64]) -> f64 {
    0.5 * r
        .iter()
        .zip(inv_mass)
        .map(|(ri, mi)| ri * ri * mi)
        .sum::<f64>()
}

/// One velocity-Verlet step with signed step size.
pub fn leapfrog<P: Potential + ?Sized>(
    pot: &mut P,
    state: &PhaseState,
    eps: f64,
    inv_mass: &[f64],
) -> PhaseState {
    let dim = state.z.len();
    let mut r_half = vec![0.0; dim];
    for i in 0..dim {
        r_half[i] = state.r[i] - 0.5 * eps * state.grad[i];
    }
    let mut z_new = vec![0.0; dim];
    for i in 0..dim {
        z_new[i] = state.z[i] + eps * inv_mass[i] * r_half[i];
    }
    let mut grad_new = vec![0.0; dim];
    let potential = pot.value_and_grad(&z_new, &mut grad_new);
    let mut r_new = r_half;
    for i in 0..dim {
        r_new[i] -= 0.5 * eps * grad_new[i];
    }
    PhaseState {
        z: z_new,
        r: r_new,
        potential,
        grad: grad_new,
    }
}

/// In-place velocity-Verlet step with signed step size: the
/// allocation-free hot-path variant of [`leapfrog`].  Updates momentum,
/// position, cached gradient and potential of `s` without touching the
/// heap — the same arithmetic, in the same order, as [`leapfrog`], so
/// the two produce bitwise-identical trajectories.
pub fn leapfrog_inplace<P: Potential + ?Sized>(
    pot: &mut P,
    s: &mut PhaseState,
    eps: f64,
    inv_mass: &[f64],
) {
    let dim = s.z.len();
    for i in 0..dim {
        s.r[i] -= 0.5 * eps * s.grad[i];
    }
    for i in 0..dim {
        s.z[i] += eps * inv_mass[i] * s.r[i];
    }
    s.potential = pot.value_and_grad(&s.z, &mut s.grad);
    for i in 0..dim {
        s.r[i] -= 0.5 * eps * s.grad[i];
    }
}

/// Hoffman-Gelman U-turn criterion across a chord (in trajectory order).
pub fn is_u_turn(
    z_left: &[f64],
    z_right: &[f64],
    r_left: &[f64],
    r_right: &[f64],
    inv_mass: &[f64],
) -> bool {
    let mut dot_l = 0.0;
    let mut dot_r = 0.0;
    for i in 0..z_left.len() {
        let dz = z_right[i] - z_left[i];
        dot_l += dz * inv_mass[i] * r_left[i];
        dot_r += dz * inv_mass[i] * r_right[i];
    }
    dot_l <= 0.0 || dot_r <= 0.0
}

/// Divergence threshold shared with the in-graph implementation.
pub const MAX_DELTA_ENERGY: f64 = 1000.0;

/// Per-draw transition statistics (shape matches the artifact outputs).
#[derive(Debug, Clone)]
pub struct Transition {
    pub z: Vec<f64>,
    pub accept_prob: f64,
    pub num_leapfrog: u32,
    pub potential: f64,
    pub diverging: bool,
    pub depth: u32,
}

/// [`Transition`] minus the proposal vector: the `Copy` result of the
/// zero-allocation draw path ([`nuts_iterative::draw_in_workspace`]),
/// whose proposal stays in the caller's workspace buffer.
#[derive(Debug, Clone, Copy)]
pub struct DrawStats {
    pub accept_prob: f64,
    pub num_leapfrog: u32,
    pub potential: f64,
    pub diverging: bool,
    pub depth: u32,
    /// The draw was *poisoned*: the potential or gradient was already
    /// non-finite at the trajectory's starting point, so no leapfrog
    /// could be taken and the proposal is the (unchanged) start
    /// position.  Distinct from `diverging`, which also covers the
    /// ordinary mid-trajectory energy blow-ups NUTS handles routinely;
    /// a poisoned draw always sets `diverging` too.  Coordinators use
    /// this to quarantine/restart a lane from its last good draw.
    pub poisoned: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Quadratic;

    impl Potential for Quadratic {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    #[test]
    fn leapfrog_is_reversible() {
        let mut pot = Quadratic;
        let mut grad = vec![0.0; 2];
        let z = vec![1.0, -0.5];
        let u = pot.value_and_grad(&z, &mut grad);
        let s0 = PhaseState {
            z,
            r: vec![0.3, 0.7],
            potential: u,
            grad,
        };
        let inv_mass = [1.0, 1.0];
        let fwd = leapfrog(&mut pot, &s0, 0.1, &inv_mass);
        // negate momentum, step forward, negate again == original
        let mut flipped = fwd.clone();
        for r in &mut flipped.r {
            *r = -*r;
        }
        let back = leapfrog(&mut pot, &flipped, 0.1, &inv_mass);
        for i in 0..2 {
            assert!((back.z[i] - s0.z[i]).abs() < 1e-12);
            assert!((-back.r[i] - s0.r[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn leapfrog_conserves_energy_for_small_eps() {
        let mut pot = Quadratic;
        let mut grad = vec![0.0; 2];
        let z = vec![1.0, 0.0];
        let u = pot.value_and_grad(&z, &mut grad);
        let mut s = PhaseState {
            z,
            r: vec![0.0, 1.0],
            potential: u,
            grad,
        };
        let inv_mass = [1.0, 1.0];
        let e0 = s.energy(&inv_mass);
        for _ in 0..1000 {
            s = leapfrog(&mut pot, &s, 0.01, &inv_mass);
        }
        assert!((s.energy(&inv_mass) - e0).abs() < 1e-4);
    }

    #[test]
    fn leapfrog_inplace_matches_allocating_leapfrog() {
        let mut pot = Quadratic;
        let mut grad = vec![0.0; 2];
        let z = vec![0.8, -1.1];
        let u = pot.value_and_grad(&z, &mut grad);
        let s0 = PhaseState {
            z,
            r: vec![0.4, -0.2],
            potential: u,
            grad,
        };
        let inv_mass = [0.9, 1.3];
        let mut inplace = s0.clone();
        let mut reference = s0;
        for _ in 0..50 {
            reference = leapfrog(&mut pot, &reference, 0.05, &inv_mass);
            leapfrog_inplace(&mut pot, &mut inplace, 0.05, &inv_mass);
            assert_eq!(inplace.z, reference.z);
            assert_eq!(inplace.r, reference.r);
            assert_eq!(inplace.grad, reference.grad);
            assert_eq!(inplace.potential, reference.potential);
        }
    }

    #[test]
    fn u_turn_detects_reversal() {
        let inv = [1.0];
        // moving apart: no U-turn
        assert!(!is_u_turn(&[0.0], &[1.0], &[1.0], &[1.0], &inv));
        // right end moving back toward left: U-turn
        assert!(is_u_turn(&[0.0], &[1.0], &[1.0], &[-1.0], &inv));
    }
}
