//! Streaming (Welford) estimation of the diagonal mass matrix, with
//! Stan's shrinkage regularization toward unit scale.

#[derive(Debug, Clone)]
pub struct Welford {
    pub mean: Vec<f64>,
    m2: Vec<f64>,
    pub count: u64,
}

impl Welford {
    pub fn new(dim: usize) -> Self {
        Welford {
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
            count: 0,
        }
    }

    pub fn update(&mut self, x: &[f64]) {
        self.count += 1;
        let n = self.count as f64;
        for i in 0..x.len() {
            let delta = x[i] - self.mean[i];
            self.mean[i] += delta / n;
            self.m2[i] += delta * (x[i] - self.mean[i]);
        }
    }

    /// Sample variance per coordinate.
    pub fn variance(&self) -> Vec<f64> {
        let denom = (self.count.max(2) - 1) as f64;
        self.m2.iter().map(|m| m / denom).collect()
    }

    /// Regularized variance (Stan: shrink toward 1e-3 with weight
    /// 5/(n+5)) — used as the inverse mass matrix diagonal.
    pub fn regularized_variance(&self) -> Vec<f64> {
        let n = self.count as f64;
        let w = n / (n + 5.0);
        self.variance()
            .iter()
            .map(|v| w * v + 1e-3 * (5.0 / (n + 5.0)))
            .collect()
    }

    pub fn reset(&mut self) {
        for v in self.mean.iter_mut().chain(self.m2.iter_mut()) {
            *v = 0.0;
        }
        self.count = 0;
    }

    /// Second central moment accumulator (checkpoint snapshot).
    pub fn m2(&self) -> &[f64] {
        &self.m2
    }

    /// Rebuild from a checkpoint snapshot; subsequent updates continue
    /// bitwise-identically.
    pub fn from_state(mean: Vec<f64>, m2: Vec<f64>, count: u64) -> Self {
        assert_eq!(mean.len(), m2.len());
        Welford { mean, m2, count }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn matches_two_pass_moments() {
        let mut rng = Rng::new(11);
        let xs: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![rng.normal() * 2.0 + 1.0, rng.normal() * 0.5])
            .collect();
        let mut w = Welford::new(2);
        for x in &xs {
            w.update(x);
        }
        for d in 0..2 {
            let mean = xs.iter().map(|x| x[d]).sum::<f64>() / xs.len() as f64;
            let var = xs.iter().map(|x| (x[d] - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
            assert!((w.mean[d] - mean).abs() < 1e-12);
            assert!((w.variance()[d] - var).abs() < 1e-10);
        }
    }

    #[test]
    fn regularization_shrinks_small_counts() {
        let mut w = Welford::new(1);
        w.update(&[10.0]);
        w.update(&[10.1]);
        let rv = w.regularized_variance()[0];
        // tiny sample: dominated by the 1e-3 * 5/(n+5) prior term
        assert!(rv < 0.01, "rv {rv}");
    }
}
