//! Plain (static-trajectory) Hamiltonian Monte Carlo.
//!
//! NUTS (§3.1) exists to remove this sampler's hand-tuned trajectory
//! length; the library ships both so the adaptivity claim is testable:
//! HMC with a poorly-chosen `num_steps` wastes leapfrogs or mixes
//! slowly, NUTS finds the turnaround automatically (see
//! `rust/tests/sampling_stats.rs::nuts_beats_mistuned_hmc_per_leapfrog`).

use crate::mcmc::{kinetic, leapfrog, PhaseState, Potential, Transition, MAX_DELTA_ENERGY};
use crate::rng::Rng;

/// One Metropolis-adjusted HMC transition with `num_steps` leapfrogs.
pub fn draw<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    num_steps: u32,
) -> Transition {
    let dim = z0.len();
    let mut grad = vec![0.0; dim];
    let potential_0 = pot.value_and_grad(z0, &mut grad);
    let mut r0 = vec![0.0; dim];
    for i in 0..dim {
        r0[i] = rng.normal() / inv_mass[i].sqrt();
    }
    let init = PhaseState {
        z: z0.to_vec(),
        r: r0,
        potential: potential_0,
        grad,
    };
    let energy_0 = init.energy(inv_mass);

    let mut state = init;
    let mut diverging = false;
    let mut steps_taken = 0u32;
    for _ in 0..num_steps {
        state = leapfrog(pot, &state, step_size, inv_mass);
        steps_taken += 1;
        let mut energy = state.potential + kinetic(&state.r, inv_mass);
        if energy.is_nan() {
            energy = f64::INFINITY;
        }
        if energy - energy_0 > MAX_DELTA_ENERGY {
            diverging = true;
            break;
        }
    }
    let energy_new = state.potential + kinetic(&state.r, inv_mass);
    let accept_prob = (energy_0 - energy_new).exp().min(1.0);
    let accepted = !diverging && rng.uniform() < accept_prob;
    Transition {
        z: if accepted { state.z } else { z0.to_vec() },
        accept_prob: if diverging { 0.0 } else { accept_prob },
        num_leapfrog: steps_taken,
        potential: if accepted { state.potential } else { potential_0 },
        diverging,
        depth: 0,
    }
}

/// [`crate::coordinator::Sampler`]-compatible wrapper.
pub struct HmcSampler<P: Potential> {
    pub potential: P,
    pub num_steps: u32,
}

impl<P: Potential> crate::coordinator::sampler::Sampler for HmcSampler<P> {
    fn dim(&self) -> usize {
        self.potential.dim()
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> anyhow::Result<Transition> {
        Ok(draw(
            &mut self.potential,
            rng,
            z,
            step_size,
            inv_mass,
            self.num_steps,
        ))
    }

    fn dispatches(&self) -> u64 {
        self.potential.num_evals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    #[test]
    fn hmc_samples_standard_gaussian() {
        let mut pot = Gauss;
        let mut rng = Rng::new(3);
        let mut z = vec![1.0, -1.0];
        let inv_mass = [1.0, 1.0];
        let mut sum = [0.0; 2];
        let mut sumsq = [0.0; 2];
        let n = 4000;
        for _ in 0..n {
            let tr = draw(&mut pot, &mut rng, &z, 0.25, &inv_mass, 8);
            z = tr.z;
            for d in 0..2 {
                sum[d] += z[d];
                sumsq[d] += z[d] * z[d];
            }
        }
        for d in 0..2 {
            let mean = sum[d] / n as f64;
            let var = sumsq[d] / n as f64 - mean * mean;
            assert!(mean.abs() < 0.12, "mean[{d}] {mean}");
            assert!((var - 1.0).abs() < 0.2, "var[{d}] {var}");
        }
    }

    #[test]
    fn hmc_rejects_on_divergence() {
        let mut pot = Gauss;
        let mut rng = Rng::new(0);
        let z = vec![30.0, 30.0];
        // absurd step size: integrator blows up, proposal rejected
        let tr = draw(&mut pot, &mut rng, &z, 50.0, &[1.0, 1.0], 10);
        assert!(tr.diverging);
        assert_eq!(tr.z, z);
    }

    #[test]
    fn hmc_accept_prob_is_one_for_tiny_steps() {
        let mut pot = Gauss;
        let mut rng = Rng::new(1);
        let tr = draw(&mut pot, &mut rng, &[0.5, 0.5], 1e-4, &[1.0, 1.0], 5);
        assert!(tr.accept_prob > 0.999);
    }
}
