//! Plain (static-trajectory) Hamiltonian Monte Carlo.
//!
//! NUTS (§3.1) exists to remove this sampler's hand-tuned trajectory
//! length; the library ships both so the adaptivity claim is testable:
//! HMC with a poorly-chosen `num_steps` wastes leapfrogs or mixes
//! slowly, NUTS finds the turnaround automatically (see
//! `rust/tests/sampling_stats.rs::nuts_beats_mistuned_hmc_per_leapfrog`).
//!
//! Like the NUTS engine, the hot path follows the workspace/scratch
//! idiom of [`crate::mcmc::nuts_iterative::draw_in_workspace`]: all
//! per-draw state lives in a caller-held [`HmcWorkspace`], integration
//! runs through [`crate::mcmc::leapfrog_inplace`], and a steady-state
//! [`draw_in_workspace`] performs **zero heap allocations**
//! (`rust/tests/alloc_free.rs`).

use crate::mcmc::{
    kinetic, leapfrog_inplace, DrawStats, PhaseState, Potential, Transition, MAX_DELTA_ENERGY,
};
use crate::rng::Rng;

/// Reusable per-draw storage for the static-trajectory HMC sampler:
/// one phase-space state (position, momentum, cached potential and
/// gradient) plus the proposal buffer the accepted/rejected position is
/// left in.
pub struct HmcWorkspace {
    dim: usize,
    /// integration state
    state: PhaseState,
    /// draw-level proposal (the result of [`draw_in_workspace`])
    z_prop: Vec<f64>,
}

impl HmcWorkspace {
    pub fn new(dim: usize) -> HmcWorkspace {
        HmcWorkspace {
            dim,
            state: PhaseState::zeros(dim),
            z_prop: vec![0.0; dim],
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The proposal left behind by the last [`draw_in_workspace`] call.
    pub fn proposal(&self) -> &[f64] {
        &self.z_prop
    }
}

/// One Metropolis-adjusted HMC transition with `num_steps` leapfrogs
/// and **zero heap allocations**: every buffer comes from `ws`, the
/// integrator is the in-place velocity Verlet, and the proposal is
/// left in `ws.z_prop` (read it via [`HmcWorkspace::proposal`]).
/// Bitwise-identical to the allocating [`draw`] wrapper (same
/// arithmetic, same RNG consumption order).
pub fn draw_in_workspace<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    ws: &mut HmcWorkspace,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    num_steps: u32,
) -> DrawStats {
    let dim = z0.len();
    assert_eq!(dim, ws.dim, "workspace dimension mismatch");

    ws.state.z.copy_from_slice(z0);
    ws.state.potential = pot.value_and_grad(z0, &mut ws.state.grad);
    for i in 0..dim {
        ws.state.r[i] = rng.normal() / inv_mass[i].sqrt();
    }
    let potential_0 = ws.state.potential;
    let energy_0 = ws.state.energy(inv_mass);

    // Containment: with a non-finite starting energy both the
    // divergence check and the MH ratio below degenerate to NaN
    // comparisons.  Reject without integrating — a poisoned draw with
    // the start position as its (unchanged) proposal.
    if !energy_0.is_finite() {
        ws.z_prop.copy_from_slice(z0);
        return DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: f64::INFINITY,
            diverging: true,
            depth: 0,
            poisoned: true,
        };
    }

    let mut diverging = false;
    let mut steps_taken = 0u32;
    for _ in 0..num_steps {
        leapfrog_inplace(pot, &mut ws.state, step_size, inv_mass);
        steps_taken += 1;
        let mut energy = ws.state.potential + kinetic(&ws.state.r, inv_mass);
        if energy.is_nan() {
            energy = f64::INFINITY;
        }
        if energy - energy_0 > MAX_DELTA_ENERGY {
            diverging = true;
            break;
        }
    }
    let energy_new = ws.state.potential + kinetic(&ws.state.r, inv_mass);
    let accept_prob = (energy_0 - energy_new).exp().min(1.0);
    let accepted = !diverging && rng.uniform() < accept_prob;
    if accepted {
        ws.z_prop.copy_from_slice(&ws.state.z);
    } else {
        ws.z_prop.copy_from_slice(z0);
    }
    DrawStats {
        accept_prob: if diverging { 0.0 } else { accept_prob },
        num_leapfrog: steps_taken,
        potential: if accepted { ws.state.potential } else { potential_0 },
        diverging,
        depth: 0,
        poisoned: false,
    }
}

/// [`draw_in_workspace`] packaged as a [`Transition`] (one proposal-
/// vector allocation per draw — everything else reuses `ws`).
pub fn draw_with<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    ws: &mut HmcWorkspace,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    num_steps: u32,
) -> Transition {
    let stats = draw_in_workspace(pot, rng, ws, z0, step_size, inv_mass, num_steps);
    Transition {
        z: ws.z_prop.clone(),
        accept_prob: stats.accept_prob,
        num_leapfrog: stats.num_leapfrog,
        potential: stats.potential,
        diverging: stats.diverging,
        depth: stats.depth,
    }
}

/// One HMC transition with a throwaway workspace (compatibility entry
/// point; persistent callers should hold an [`HmcWorkspace`] and use
/// [`draw_with`] / [`draw_in_workspace`]).
pub fn draw<P: Potential + ?Sized>(
    pot: &mut P,
    rng: &mut Rng,
    z0: &[f64],
    step_size: f64,
    inv_mass: &[f64],
    num_steps: u32,
) -> Transition {
    let mut ws = HmcWorkspace::new(z0.len());
    draw_with(pot, rng, &mut ws, z0, step_size, inv_mass, num_steps)
}

/// [`crate::coordinator::Sampler`]-compatible wrapper holding a
/// persistent [`HmcWorkspace`], so its per-draw hot path is
/// allocation-free (one proposal-vector allocation per draw to fill
/// the returned [`Transition`]).
pub struct HmcSampler<P: Potential> {
    pub potential: P,
    pub num_steps: u32,
    workspace: Option<HmcWorkspace>,
}

impl<P: Potential> HmcSampler<P> {
    pub fn new(potential: P, num_steps: u32) -> HmcSampler<P> {
        HmcSampler {
            potential,
            num_steps,
            workspace: None,
        }
    }
}

impl<P: Potential> crate::coordinator::sampler::Sampler for HmcSampler<P> {
    fn dim(&self) -> usize {
        self.potential.dim()
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> anyhow::Result<Transition> {
        let dim = self.potential.dim();
        let stale = match &self.workspace {
            Some(w) => w.dim() != dim,
            None => true,
        };
        if stale {
            self.workspace = Some(HmcWorkspace::new(dim));
        }
        let ws = self.workspace.as_mut().expect("workspace just ensured");
        Ok(draw_with(
            &mut self.potential,
            rng,
            ws,
            z,
            step_size,
            inv_mass,
            self.num_steps,
        ))
    }

    fn dispatches(&self) -> u64 {
        self.potential.num_evals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    #[test]
    fn hmc_samples_standard_gaussian() {
        let mut pot = Gauss;
        let mut rng = Rng::new(3);
        let mut z = vec![1.0, -1.0];
        let inv_mass = [1.0, 1.0];
        let mut sum = [0.0; 2];
        let mut sumsq = [0.0; 2];
        let n = 4000;
        for _ in 0..n {
            let tr = draw(&mut pot, &mut rng, &z, 0.25, &inv_mass, 8);
            z = tr.z;
            for d in 0..2 {
                sum[d] += z[d];
                sumsq[d] += z[d] * z[d];
            }
        }
        for d in 0..2 {
            let mean = sum[d] / n as f64;
            let var = sumsq[d] / n as f64 - mean * mean;
            assert!(mean.abs() < 0.12, "mean[{d}] {mean}");
            assert!((var - 1.0).abs() < 0.2, "var[{d}] {var}");
        }
    }

    #[test]
    fn hmc_rejects_on_divergence() {
        let mut pot = Gauss;
        let mut rng = Rng::new(0);
        let z = vec![30.0, 30.0];
        // absurd step size: integrator blows up, proposal rejected
        let tr = draw(&mut pot, &mut rng, &z, 50.0, &[1.0, 1.0], 10);
        assert!(tr.diverging);
        assert_eq!(tr.z, z);
    }

    #[test]
    fn hmc_accept_prob_is_one_for_tiny_steps() {
        let mut pot = Gauss;
        let mut rng = Rng::new(1);
        let tr = draw(&mut pot, &mut rng, &[0.5, 0.5], 1e-4, &[1.0, 1.0], 5);
        assert!(tr.accept_prob > 0.999);
    }

    /// Workspace reuse must not change anything: a fresh workspace per
    /// draw and one long-lived workspace produce bitwise-equal chains.
    #[test]
    fn hmc_workspace_reuse_is_bitwise_deterministic() {
        let mut rng_fresh = Rng::new(7);
        let mut rng_reuse = Rng::new(7);
        let mut pot_a = Gauss;
        let mut pot_b = Gauss;
        let mut ws = HmcWorkspace::new(2);
        let inv_mass = [0.9, 1.3];
        let mut z_fresh = vec![0.3, -0.8];
        let mut z_reuse = z_fresh.clone();
        for _ in 0..25 {
            let a = draw(&mut pot_a, &mut rng_fresh, &z_fresh, 0.2, &inv_mass, 6);
            let b = draw_with(&mut pot_b, &mut rng_reuse, &mut ws, &z_reuse, 0.2, &inv_mass, 6);
            assert_eq!(a.z, b.z);
            assert_eq!(a.num_leapfrog, b.num_leapfrog);
            assert_eq!(a.accept_prob, b.accept_prob);
            assert_eq!(a.potential, b.potential);
            z_fresh = a.z;
            z_reuse = b.z;
        }
    }
}
