//! Streaming row loaders and the deterministic minibatch scheduler —
//! the data side of subsampled SVI (ROADMAP open item 4, the paper's
//! tall-data regime).
//!
//! A [`RowLoader`] yields one `(x_i, y_i)` row at a time, so the ELBO
//! hot path only ever touches the `B` rows of the current minibatch:
//! [`SyntheticLogisticStream`] regenerates row `i` on demand from
//! `(seed, i)` and stores nothing but its true weight vector, which is
//! how a 10M-row logistic regression fits in a few hundred bytes of
//! loader state.  [`InMemoryRows`] wraps an already-materialized
//! matrix for small problems and tests.
//!
//! [`MinibatchScheduler`] reproduces the Pyro `plate(...,
//! subsample_size=B)` sampling contract: each epoch is a fresh
//! Fisher–Yates shuffle of `0..N` (one dedicated xoshiro stream),
//! served in consecutive windows of `B` with the ragged tail dropped,
//! so every row appears at most once per epoch and exactly once when
//! `B` divides `N` — the property the unbiasedness of the scaled ELBO
//! estimator rests on.  Scheduling is deterministic in the seed and
//! checkpointable: a [`SubsampleCursor`] (epoch, position, and the RNG
//! state snapshotted at the *start* of the epoch) is enough to rebuild
//! the permutation and resume bitwise-identically
//! (`rust/tests/subsampling.rs`).

use crate::obs::{Counter, Recorder};
use crate::ppl::special::sigmoid;
use crate::rng::Rng;

/// A source of `(covariates, label)` rows addressed by index — the
/// only interface the subsampled models see, so swapping a synthetic
/// stream for a memory-mapped file never touches the model.
///
/// Implementations must be deterministic: `load_row(i)` always yields
/// the same row, regardless of call order (minibatch gathers jump
/// around the index space).
pub trait RowLoader {
    /// Total number of rows `N` in the (possibly virtual) dataset.
    fn num_rows(&self) -> usize;
    /// Covariate dimension `d`.
    fn dim(&self) -> usize;
    /// Write row `i`'s covariates into `x_out` (length `d`) and return
    /// its label.
    fn load_row(&self, i: usize, x_out: &mut [f64]) -> f64;
}

/// A virtual logistic-regression dataset generated row-by-row from the
/// seed: standard-normal covariates, labels drawn from
/// `Bernoulli(sigmoid(x . w_true - 0.5))` with a sparse `w_true` — the
/// same recipe as [`crate::data::make_covtype_like`], but **never
/// materialized**.  Memory is `O(d)` no matter how many rows, so this
/// is the 10M-row workload of the subsampling acceptance tests.
#[derive(Debug, Clone)]
pub struct SyntheticLogisticStream {
    seed: u64,
    n: usize,
    d: usize,
    w_true: Vec<f64>,
}

impl SyntheticLogisticStream {
    /// Build the virtual dataset: draws `w_true` (each coordinate a
    /// unit normal with probability 0.3, else exactly zero) from
    /// `seed` and records the row-generation seed.  No rows are
    /// generated here.
    pub fn new(seed: u64, n: usize, d: usize) -> SyntheticLogisticStream {
        assert!(n > 0 && d > 0, "SyntheticLogisticStream: empty shape");
        let mut rng = Rng::new(seed ^ 0xC0FF_EE00_D15E_A5E5);
        let w_true: Vec<f64> = (0..d)
            .map(|_| if rng.bernoulli(0.3) { rng.normal() } else { 0.0 })
            .collect();
        SyntheticLogisticStream { seed, n, d, w_true }
    }

    /// The generating weight vector (for posterior-recovery checks).
    pub fn w_true(&self) -> &[f64] {
        &self.w_true
    }
}

impl RowLoader for SyntheticLogisticStream {
    fn num_rows(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn load_row(&self, i: usize, x_out: &mut [f64]) -> f64 {
        assert!(i < self.n, "row index {i} out of range (n = {})", self.n);
        assert_eq!(x_out.len(), self.d, "row buffer must have length d");
        // a private xoshiro stream per row: splitmix over (seed, i)
        // gives independent, order-free row generation
        let mut rng = Rng::new(
            self.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.fill_normal(x_out);
        let logit: f64 = x_out
            .iter()
            .zip(&self.w_true)
            .map(|(a, b)| a * b)
            .sum::<f64>()
            - 0.5;
        if rng.bernoulli(sigmoid(logit)) {
            1.0
        } else {
            0.0
        }
    }
}

/// A [`RowLoader`] over an already-materialized row-major matrix —
/// the bridge from [`crate::data::make_covtype_like`]-style datasets
/// (and the tool for full-batch-equivalence tests, where the same
/// rows must reach both the plain and the subsampled model).
#[derive(Debug, Clone)]
pub struct InMemoryRows {
    /// row-major (n, d)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub d: usize,
}

impl InMemoryRows {
    pub fn new(x: Vec<f64>, y: Vec<f64>, n: usize, d: usize) -> InMemoryRows {
        assert_eq!(x.len(), n * d, "InMemoryRows: x must be n x d");
        assert_eq!(y.len(), n, "InMemoryRows: y must have n rows");
        InMemoryRows { x, y, n, d }
    }
}

impl RowLoader for InMemoryRows {
    fn num_rows(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn load_row(&self, i: usize, x_out: &mut [f64]) -> f64 {
        x_out.copy_from_slice(&self.x[i * self.d..(i + 1) * self.d]);
        self.y[i]
    }
}

/// Everything needed to resume a [`MinibatchScheduler`]
/// bitwise-identically: the epoch counter, the position within the
/// epoch's permutation, and the RNG state snapshotted at the **start**
/// of the epoch (before its shuffle) — replaying the shuffle from that
/// state rebuilds the identical permutation, so a restored scheduler
/// serves the exact index sequence the original would have.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubsampleCursor {
    pub epoch: u64,
    pub pos: usize,
    pub rng_s: [u64; 4],
    pub rng_spare: Option<f64>,
}

/// Deterministic epoch-shuffling minibatch scheduler (see the module
/// docs for the contract).  Drive it with [`MinibatchScheduler::next_batch`];
/// snapshot/restore with [`MinibatchScheduler::cursor`] /
/// [`MinibatchScheduler::from_cursor`].
#[derive(Debug, Clone)]
pub struct MinibatchScheduler {
    total: usize,
    batch: usize,
    /// this epoch's permutation of `0..total`
    perm: Vec<usize>,
    /// next unread offset into `perm`
    pos: usize,
    epoch: u64,
    rng: Rng,
    /// RNG state at the start of the current epoch (pre-shuffle)
    epoch_state: ([u64; 4], Option<f64>),
    /// Flight recorder ([`crate::obs`]): epochs completed and rows
    /// streamed — pure counters, never touches the shuffle RNG.
    recorder: Recorder,
}

impl MinibatchScheduler {
    /// Build a scheduler over `total` rows serving batches of `batch`,
    /// drawing its shuffles from `rng` (hand it a dedicated
    /// [`Rng::split`] stream so subsampling never perturbs the SVI
    /// noise sequence).  When `batch == total` the scheduler is the
    /// **identity**: no shuffle is performed and the RNG is never
    /// advanced, so full-batch runs are bitwise-identical to the
    /// non-subsampled path.
    pub fn new(total: usize, batch: usize, rng: Rng) -> MinibatchScheduler {
        assert!(
            batch > 0 && batch <= total,
            "MinibatchScheduler: need 0 < batch ({batch}) <= total ({total})"
        );
        let mut s = MinibatchScheduler {
            total,
            batch,
            perm: (0..total).collect(),
            pos: 0,
            epoch: 0,
            rng,
            epoch_state: ([0; 4], None),
            recorder: Recorder::global(),
        };
        s.begin_epoch();
        s
    }

    /// Point this scheduler's flight-recorder counters at an explicit
    /// registry (tests; normal construction picks up the global one).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    /// Snapshot the RNG, reset the permutation to identity, and (unless
    /// full-batch) shuffle it — the one place randomness enters, and
    /// exactly what [`MinibatchScheduler::from_cursor`] replays.
    fn begin_epoch(&mut self) {
        self.epoch_state = self.rng.state();
        self.pos = 0;
        for (i, p) in self.perm.iter_mut().enumerate() {
            *p = i;
        }
        if self.batch < self.total {
            self.rng.shuffle(&mut self.perm);
        }
    }

    /// The next minibatch of row indices.  Consecutive windows of the
    /// epoch's permutation; when fewer than `batch` indices remain the
    /// ragged tail is dropped and a fresh epoch begins.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.pos + self.batch > self.total {
            self.epoch += 1;
            self.recorder.incr(Counter::Epochs);
            self.begin_epoch();
        }
        self.recorder.add(Counter::RowsStreamed, self.batch as u64);
        let b = &self.perm[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        b
    }

    /// Completed-epoch counter (0 while serving the first epoch).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Minibatch size `B`.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Population size `N`.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Number of batches served per epoch (`floor(N / B)`).
    pub fn batches_per_epoch(&self) -> usize {
        self.total / self.batch
    }

    /// Snapshot the resume state (see [`SubsampleCursor`]).
    pub fn cursor(&self) -> SubsampleCursor {
        SubsampleCursor {
            epoch: self.epoch,
            pos: self.pos,
            rng_s: self.epoch_state.0,
            rng_spare: self.epoch_state.1,
        }
    }

    /// Rebuild a scheduler mid-stream from a [`SubsampleCursor`]:
    /// restores the epoch-start RNG state, replays the epoch's shuffle,
    /// and seeks to the recorded position — the resumed scheduler's
    /// index sequence is bitwise-identical to the original's.
    pub fn from_cursor(total: usize, batch: usize, cur: &SubsampleCursor) -> MinibatchScheduler {
        let rng = Rng::from_state(cur.rng_s, cur.rng_spare);
        let mut s = MinibatchScheduler::new(total, batch, rng);
        s.epoch = cur.epoch;
        s.pos = cur.pos;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_stream_rows_are_deterministic_and_order_free() {
        let s = SyntheticLogisticStream::new(9, 1000, 4);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        let ya = s.load_row(777, &mut a);
        // touching other rows in between must not change row 777
        let _ = s.load_row(3, &mut b);
        let _ = s.load_row(999, &mut b);
        let yb = s.load_row(777, &mut b);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
        assert!(ya == 0.0 || ya == 1.0);
    }

    #[test]
    fn synthetic_stream_labels_correlate_with_truth() {
        let s = SyntheticLogisticStream::new(4, 4000, 6);
        let mut x = vec![0.0; 6];
        let (mut mp, mut np, mut mn, mut nn) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..s.num_rows() {
            let y = s.load_row(i, &mut x);
            let score: f64 = x.iter().zip(s.w_true()).map(|(a, b)| a * b).sum();
            if y > 0.5 {
                mp += score;
                np += 1.0;
            } else {
                mn += score;
                nn += 1.0;
            }
        }
        assert!(np > 0.0 && nn > 0.0);
        assert!(mp / np > mn / nn + 0.3, "{} vs {}", mp / np, mn / nn);
    }

    #[test]
    fn scheduler_epoch_is_a_permutation_and_deterministic() {
        let n = 20;
        let mut s1 = MinibatchScheduler::new(n, 5, Rng::new(3));
        let mut s2 = MinibatchScheduler::new(n, 5, Rng::new(3));
        let mut seen = vec![false; n];
        for _ in 0..4 {
            let b1: Vec<usize> = s1.next_batch().to_vec();
            let b2: Vec<usize> = s2.next_batch().to_vec();
            assert_eq!(b1, b2);
            for &i in &b1 {
                assert!(!seen[i], "row {i} repeated within an epoch");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "epoch did not cover every row");
        assert_eq!(s1.epoch(), 0);
        let _ = s1.next_batch();
        assert_eq!(s1.epoch(), 1);
    }

    #[test]
    fn full_batch_scheduler_is_identity_and_rng_free() {
        let mut rng = Rng::new(7);
        let before = rng.state();
        let mut s = MinibatchScheduler::new(6, 6, rng);
        for _ in 0..3 {
            assert_eq!(s.next_batch(), &[0, 1, 2, 3, 4, 5]);
        }
        // the scheduler never consumed randomness
        assert_eq!(s.cursor().rng_s, before.0);
    }

    #[test]
    fn ragged_tail_is_dropped() {
        let mut s = MinibatchScheduler::new(10, 3, Rng::new(1));
        assert_eq!(s.batches_per_epoch(), 3);
        for _ in 0..3 {
            assert_eq!(s.next_batch().len(), 3);
        }
        assert_eq!(s.epoch(), 0);
        let _ = s.next_batch(); // tail of 1 dropped; epoch rolls
        assert_eq!(s.epoch(), 1);
    }

    #[test]
    fn cursor_resume_is_bitwise_identical() {
        let mut a = MinibatchScheduler::new(50, 7, Rng::new(11));
        for _ in 0..10 {
            let _ = a.next_batch();
        }
        let cur = a.cursor();
        let mut b = MinibatchScheduler::from_cursor(50, 7, &cur);
        for _ in 0..30 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }
}
