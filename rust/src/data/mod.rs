//! Synthetic workload generators (Appendix C of the paper; CovType is
//! substituted per DESIGN.md §5).  All generators are deterministic in
//! the seed and produce both the raw arrays and the [`HostTensor`]s the
//! artifacts take as inputs.

pub mod stream;

use crate::ppl::special::sigmoid;
use crate::rng::Rng;
use crate::runtime::engine::HostTensor;
use crate::runtime::manifest::DType;

pub use stream::{
    InMemoryRows, MinibatchScheduler, RowLoader, SubsampleCursor, SyntheticLogisticStream,
};

/// Semi-supervised HMM sequence (K states, V categories), sticky
/// transitions + informative emissions as in
/// `python/compile/models/hmm.py::make_hmm_data`.
pub struct HmmData {
    pub obs: Vec<usize>,
    pub sup_states: Vec<usize>,
    pub theta_true: Vec<f64>,
    pub phi_true: Vec<f64>,
    pub num_states: usize,
    pub num_categories: usize,
}

pub fn make_hmm(seed: u64, seq_len: usize, num_supervised: usize, k: usize, v: usize) -> HmmData {
    let mut rng = Rng::new(seed);
    // sticky transition rows: Dirichlet(1 + 4 I)
    let mut theta = vec![0.0; k * k];
    for i in 0..k {
        let alpha: Vec<f64> = (0..k).map(|j| if i == j { 5.0 } else { 1.0 }).collect();
        let row = rng.dirichlet(&alpha);
        theta[i * k..(i + 1) * k].copy_from_slice(&row);
    }
    // informative emissions: Dirichlet(1 + 6 one_hot(i * V/K))
    let mut phi = vec![0.0; k * v];
    for i in 0..k {
        let peak = i * (v / k);
        let alpha: Vec<f64> = (0..v).map(|w| if w == peak { 7.0 } else { 1.0 }).collect();
        let row = rng.dirichlet(&alpha);
        phi[i * v..(i + 1) * v].copy_from_slice(&row);
    }
    let mut obs = Vec::with_capacity(seq_len);
    let mut states = Vec::with_capacity(seq_len);
    let mut z = 0usize;
    for _ in 0..seq_len {
        z = rng.categorical(&theta[z * k..(z + 1) * k]);
        states.push(z);
        obs.push(rng.categorical(&phi[z * v..(z + 1) * v]));
    }
    HmmData {
        obs,
        sup_states: states[..num_supervised].to_vec(),
        theta_true: theta,
        phi_true: phi,
        num_states: k,
        num_categories: v,
    }
}

impl HmmData {
    /// Artifact inputs: (obs i32[T], sup_states i32[T_sup]).
    pub fn tensors(&self) -> Vec<HostTensor> {
        vec![
            HostTensor::I32(
                self.obs.iter().map(|&x| x as i32).collect(),
                vec![self.obs.len()],
            ),
            HostTensor::I32(
                self.sup_states.iter().map(|&x| x as i32).collect(),
                vec![self.sup_states.len()],
            ),
        ]
    }
}

/// CovType-substitute logistic regression design (DESIGN.md §5:
/// standardized features, sparse logit-linear labels, class imbalance).
pub struct LogisticData {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub w_true: Vec<f64>,
    pub n: usize,
    pub d: usize,
}

pub fn make_covtype_like(seed: u64, n: usize, d: usize) -> LogisticData {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0; n * d];
    rng.fill_normal(&mut x);
    let w_true: Vec<f64> = (0..d)
        .map(|_| {
            if rng.bernoulli(0.3) {
                rng.normal()
            } else {
                0.0
            }
        })
        .collect();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let xi = &x[i * d..(i + 1) * d];
        let logit: f64 = xi.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>() - 0.5;
        y[i] = if rng.bernoulli(sigmoid(logit)) { 1.0 } else { 0.0 };
    }
    LogisticData {
        x,
        y,
        w_true,
        n,
        d,
    }
}

impl LogisticData {
    /// Artifact inputs: (x float[N,D], y i32[N]).
    pub fn tensors(&self, dtype: DType) -> anyhow::Result<Vec<HostTensor>> {
        Ok(vec![
            HostTensor::from_f64(&self.x, &[self.n, self.d], dtype)?,
            HostTensor::I32(self.y.iter().map(|&v| v as i32).collect(), vec![self.n]),
        ])
    }
}

/// SKIM synthetic data: 3 random pairwise interactions among p
/// covariates (paper Appendix C).
pub struct SkimData {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub pairs: Vec<(usize, usize)>,
    pub n: usize,
    pub p: usize,
}

pub fn make_skim(seed: u64, n: usize, p: usize, num_pairs: usize) -> SkimData {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0; n * p];
    rng.fill_normal(&mut x);
    let idx = rng.choose(p, 2 * num_pairs);
    let pairs: Vec<(usize, usize)> = idx.chunks(2).map(|c| (c[0], c[1])).collect();
    let coefs: Vec<f64> = (0..num_pairs).map(|_| 1.0 + rng.normal().abs()).collect();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let xi = &x[i * p..(i + 1) * p];
        let mut v = 0.0;
        for (q, &(a, b)) in pairs.iter().enumerate() {
            v += coefs[q] * xi[a] * xi[b] + 0.5 * (xi[a] + xi[b]);
        }
        y[i] = v + 0.3 * rng.normal();
    }
    SkimData { x, y, pairs, n, p }
}

impl SkimData {
    /// Artifact inputs: (x float[N,P], y float[N]).
    pub fn tensors(&self, dtype: DType) -> anyhow::Result<Vec<HostTensor>> {
        Ok(vec![
            HostTensor::from_f64(&self.x, &[self.n, self.p], dtype)?,
            HostTensor::from_f64(&self.y, &[self.n], dtype)?,
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hmm_data_shapes_and_ranges() {
        let d = make_hmm(0, 600, 100, 3, 10);
        assert_eq!(d.obs.len(), 600);
        assert_eq!(d.sup_states.len(), 100);
        assert!(d.obs.iter().all(|&o| o < 10));
        assert!(d.sup_states.iter().all(|&s| s < 3));
        // rows are simplexes
        for i in 0..3 {
            let s: f64 = d.theta_true[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn covtype_labels_correlate_with_truth() {
        let d = make_covtype_like(1, 5000, 10);
        // score = x @ w_true should separate classes
        let mut mean_pos = 0.0;
        let mut mean_neg = 0.0;
        let (mut np, mut nn) = (0.0, 0.0);
        for i in 0..d.n {
            let s: f64 = d.x[i * d.d..(i + 1) * d.d]
                .iter()
                .zip(&d.w_true)
                .map(|(a, b)| a * b)
                .sum();
            if d.y[i] > 0.5 {
                mean_pos += s;
                np += 1.0;
            } else {
                mean_neg += s;
                nn += 1.0;
            }
        }
        assert!(mean_pos / np > mean_neg / nn + 0.5);
    }

    #[test]
    fn skim_pairs_are_distinct() {
        let d = make_skim(2, 200, 50, 3);
        let mut seen = std::collections::HashSet::new();
        for (a, b) in &d.pairs {
            assert!(a != b);
            assert!(seen.insert(*a) && seen.insert(*b), "overlapping pairs");
        }
        assert_eq!(d.y.len(), 200);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = make_covtype_like(7, 100, 5);
        let b = make_covtype_like(7, 100, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
