//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and exposes typed handles to the rest of the
//! coordinator.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (signatures, param
//!   layouts, workload metadata); the runtime is entirely
//!   manifest-driven, no artifact names are hard-coded.
//! * [`engine`] — PJRT CPU client + compile cache: HLO text ->
//!   `HloModuleProto` -> compile, once per artifact.
//! * [`handles`] — high-level wrappers: [`handles::NutsStep`] (the
//!   paper's fused transition; data uploaded to device once, per-draw
//!   inputs marshalled per call) and [`handles::PjrtPotential`] (the
//!   Pyro-architecture baseline: a [`crate::mcmc::Potential`] that pays
//!   one PJRT dispatch per leapfrog).

pub mod engine;
pub mod handles;
pub mod manifest;

pub use engine::{Engine, Executable, HostTensor};
pub use handles::{NutsStep, PjrtPotential};
pub use manifest::{ArtifactEntry, DType, Manifest, ParamSpan, TensorSpec};
