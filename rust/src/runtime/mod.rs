//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and exposes typed handles to the rest of the
//! coordinator.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (signatures, param
//!   layouts, workload metadata); the runtime is entirely
//!   manifest-driven, no artifact names are hard-coded.
//! * [`engine`] — PJRT CPU client + compile cache: HLO text ->
//!   `HloModuleProto` -> compile, once per artifact.
//! * [`handles`] — high-level wrappers: [`handles::NutsStep`] (the
//!   paper's fused transition; data uploaded to device once, per-draw
//!   inputs marshalled per call) and [`handles::PjrtPotential`] (the
//!   Pyro-architecture baseline: a [`crate::mcmc::Potential`] that pays
//!   one PJRT dispatch per leapfrog).
//!
//! The real engine/handles need the `xla` bindings and a libxla
//! install, so they are gated behind the non-default **`pjrt`** cargo
//! feature.  The default build substitutes API-identical stubs
//! (`engine_stub.rs` / `handles_stub.rs`): the manifest still loads and
//! every native (Stan-architecture) code path works, while constructing
//! a PJRT executable/buffer returns a descriptive error.  This keeps
//! `cargo build && cargo test` fully offline-green on machines without
//! libxla.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod handles;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "handles_stub.rs"]
pub mod handles;

pub use engine::{Engine, Executable, HostTensor};
pub use handles::{NutsStep, PjrtPotential};
pub use manifest::{ArtifactEntry, DType, Manifest, ParamSpan, TensorSpec};
