//! High-level artifact handles.
//!
//! [`NutsStep`] is the paper's architecture: the entire NUTS transition
//! is ONE compiled executable; the coordinator calls it once per draw.
//! Dataset tensors are uploaded to the device a single time at
//! construction and stay resident — per-draw marshalling is O(dim).
//!
//! [`PjrtPotential`] is the Pyro-architecture comparator: only
//! `potential_and_grad` is compiled, and the host-side tree builder
//! ([`crate::mcmc::nuts_recursive`]) pays one dispatch per leapfrog —
//! exactly the overhead §3.1 of the paper attributes to Pyro.

use anyhow::{bail, Context, Result};

use crate::mcmc::{Potential, Transition};
use crate::runtime::engine::{literal_scalar_f64, literal_to_f64, Engine, HostTensor};
use crate::runtime::manifest::DType;

use std::rc::Rc;

use super::engine::Executable;

fn upload_data(
    engine: &Engine,
    exe: &Executable,
    skip: usize,
    data: &[HostTensor],
) -> Result<Vec<xla::PjRtBuffer>> {
    let expected = exe.entry.inputs.len() - skip;
    if data.len() != expected {
        bail!(
            "artifact {} expects {} data inputs, got {}",
            exe.entry.name,
            expected,
            data.len()
        );
    }
    data.iter().map(|t| engine.upload(t)).collect()
}

/// Fused end-to-end NUTS transition (the paper's headline).
pub struct NutsStep {
    client: xla::PjRtClient,
    exe: Rc<Executable>,
    data_bufs: Vec<xla::PjRtBuffer>,
    pub dim: usize,
    dtype: DType,
    /// PJRT dispatches so far (one per draw — the benchmark's point).
    pub dispatches: u64,
    // §Perf: step size and inverse mass change only at adaptation
    // boundaries; cache their device buffers between draws.
    eps_cache: Option<(f64, xla::PjRtBuffer)>,
    mass_cache: Option<(Vec<f64>, xla::PjRtBuffer)>,
}

impl NutsStep {
    /// `name` is a manifest key of kind `nuts_step` (or `nuts_step_vmap`).
    pub fn new(engine: &Engine, name: &str, data: &[HostTensor]) -> Result<NutsStep> {
        let exe = engine.executable(name)?;
        if !exe.entry.kind.starts_with("nuts_step") {
            bail!("artifact {name} has kind {}, want nuts_step*", exe.entry.kind);
        }
        let data_bufs = upload_data(engine, &exe, 4, data)?;
        let dtype = exe.entry.inputs[1].dtype;
        let dim = exe.entry.dim;
        Ok(NutsStep {
            client: engine.client.clone(),
            exe,
            data_bufs,
            dim,
            dtype,
            dispatches: 0,
            eps_cache: None,
            mass_cache: None,
        })
    }

    pub fn entry(&self) -> &super::manifest::ArtifactEntry {
        &self.exe.entry
    }

    /// One NUTS draw: `(key, z, step_size, inv_mass)` -> transition.
    pub fn step(
        &mut self,
        key: [u32; 2],
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition> {
        debug_assert_eq!(z.len(), self.dim);
        let key_b = HostTensor::U32(key.to_vec(), vec![2]).to_buffer(&self.client)?;
        let z_b = HostTensor::from_f64(z, &[self.dim], self.dtype)?.to_buffer(&self.client)?;
        if !matches!(&self.eps_cache, Some((e, _)) if *e == step_size) {
            let buf = HostTensor::from_f64(&[step_size], &[], self.dtype)?
                .to_buffer(&self.client)?;
            self.eps_cache = Some((step_size, buf));
        }
        if !matches!(&self.mass_cache, Some((m, _)) if m == inv_mass) {
            let buf = HostTensor::from_f64(inv_mass, &[self.dim], self.dtype)?
                .to_buffer(&self.client)?;
            self.mass_cache = Some((inv_mass.to_vec(), buf));
        }
        let eps_b = &self.eps_cache.as_ref().unwrap().1;
        let mass_b = &self.mass_cache.as_ref().unwrap().1;

        let mut args: Vec<&xla::PjRtBuffer> = vec![&key_b, &z_b, eps_b, mass_b];
        args.extend(self.data_bufs.iter());
        self.dispatches += 1;
        let outs = self.exe.run_buffers(&args)?;
        parse_transition(&outs, 0, self.dim)
    }

    /// Vmapped multi-chain draw (artifact kind `nuts_step_vmap`):
    /// all per-chain states advance in one dispatch (§3.2, E7).
    pub fn step_vmap(
        &mut self,
        keys: &[[u32; 2]],
        zs: &[f64],
        step_sizes: &[f64],
        inv_masses: &[f64],
    ) -> Result<Vec<Transition>> {
        let k = keys.len();
        debug_assert_eq!(zs.len(), k * self.dim);
        let keys_flat: Vec<u32> = keys.iter().flat_map(|k| k.iter().copied()).collect();
        let keys_b = HostTensor::U32(keys_flat, vec![k, 2]).to_buffer(&self.client)?;
        let z_b =
            HostTensor::from_f64(zs, &[k, self.dim], self.dtype)?.to_buffer(&self.client)?;
        let eps_b = HostTensor::from_f64(step_sizes, &[k], self.dtype)?.to_buffer(&self.client)?;
        let mass_b = HostTensor::from_f64(inv_masses, &[k, self.dim], self.dtype)?
            .to_buffer(&self.client)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&keys_b, &z_b, &eps_b, &mass_b];
        args.extend(self.data_bufs.iter());
        self.dispatches += 1;
        let outs = self.exe.run_buffers(&args)?;
        (0..k).map(|c| parse_transition(&outs, c, self.dim)).collect()
    }
}

fn parse_transition(outs: &[xla::Literal], chain: usize, dim: usize) -> Result<Transition> {
    let z_all = literal_to_f64(&outs[0])?;
    let z = z_all[chain * dim..(chain + 1) * dim].to_vec();
    let pick = |lit: &xla::Literal| -> Result<f64> {
        let v = literal_to_f64(lit)?;
        Ok(v[chain.min(v.len() - 1)])
    };
    Ok(Transition {
        z,
        accept_prob: pick(&outs[1])?,
        num_leapfrog: pick(&outs[2])? as u32,
        potential: pick(&outs[3])?,
        diverging: pick(&outs[4])? != 0.0,
        depth: pick(&outs[5])? as u32,
    })
}

/// Pyro-architecture comparator: potential + gradient as the only
/// compiled callable, dispatched once per leapfrog by the host-side
/// tree builder.
pub struct PjrtPotential {
    client: xla::PjRtClient,
    exe: Rc<Executable>,
    data_bufs: Vec<xla::PjRtBuffer>,
    pub dim: usize,
    dtype: DType,
    evals: u64,
}

impl PjrtPotential {
    pub fn new(engine: &Engine, name: &str, data: &[HostTensor]) -> Result<PjrtPotential> {
        let exe = engine.executable(name)?;
        if exe.entry.kind != "potential_and_grad" {
            bail!(
                "artifact {name} has kind {}, want potential_and_grad",
                exe.entry.kind
            );
        }
        let data_bufs = upload_data(engine, &exe, 1, data)?;
        let dtype = exe.entry.inputs[0].dtype;
        let dim = exe.entry.dim;
        Ok(PjrtPotential {
            client: engine.client.clone(),
            exe,
            data_bufs,
            dim,
            dtype,
            evals: 0,
        })
    }

    pub fn eval(&mut self, z: &[f64], grad: &mut [f64]) -> Result<f64> {
        let z_b = HostTensor::from_f64(z, &[self.dim], self.dtype)?.to_buffer(&self.client)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&z_b];
        args.extend(self.data_bufs.iter());
        self.evals += 1;
        let outs = self.exe.run_buffers(&args)?;
        let g = literal_to_f64(&outs[1])?;
        grad.copy_from_slice(&g);
        literal_scalar_f64(&outs[0])
    }
}

impl Potential for PjrtPotential {
    fn dim(&self) -> usize {
        self.dim
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.eval(z, grad)
            .context("PJRT potential dispatch failed")
            .unwrap()
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}
