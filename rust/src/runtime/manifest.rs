//! `artifacts/manifest.json` — the contract between `aot.py` (writer)
//! and the Rust runtime (reader).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    I32,
    U32,
    Bool,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "float64" => DType::F64,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            "bool" => DType::Bool,
            other => bail!("unsupported dtype in manifest: {other}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One latent site's span in the flat unconstrained vector.
#[derive(Debug, Clone)]
pub struct ParamSpan {
    pub site: String,
    pub offset: usize,
    pub size: usize,
    pub unconstrained_shape: Vec<usize>,
    pub constrained_shape: Vec<usize>,
    pub support: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "f32" | "f64"
    pub dtype: String,
    /// "nuts_step" | "potential_and_grad" | "nuts_step_vmap" | ...
    pub kind: String,
    pub model: String,
    pub dim: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub param_layout: Vec<ParamSpan>,
    /// remaining metadata (n, p, seq_len, chains, ...)
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactEntry {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("tensor spec missing name"))?
                    .to_string(),
                dtype: DType::parse(
                    e.get("dtype")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("tensor spec missing dtype"))?,
                )?,
                shape: e
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

fn param_layout(j: Option<&Json>) -> Result<Vec<ParamSpan>> {
    let Some(j) = j else {
        return Ok(Vec::new());
    };
    j.as_arr()
        .ok_or_else(|| anyhow!("param_layout must be an array"))?
        .iter()
        .map(|e| {
            let shape = |key: &str| -> Vec<usize> {
                e.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                    .unwrap_or_default()
            };
            Ok(ParamSpan {
                site: e
                    .get("site")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("param span missing site"))?
                    .to_string(),
                offset: e.get("offset").and_then(|v| v.as_usize()).unwrap_or(0),
                size: e.get("size").and_then(|v| v.as_usize()).unwrap_or(0),
                unconstrained_shape: shape("unconstrained_shape"),
                constrained_shape: shape("constrained_shape"),
                support: e
                    .get("support")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut entries = BTreeMap::new();
        for e in root
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let obj = e.as_obj().ok_or_else(|| anyhow!("entry must be object"))?;
            let get_str = |k: &str| -> Result<String> {
                obj.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("entry missing '{k}'"))
            };
            let known = [
                "name",
                "file",
                "dtype",
                "kind",
                "model",
                "dim",
                "inputs",
                "outputs",
                "param_layout",
            ];
            let meta: BTreeMap<String, Json> = obj
                .iter()
                .filter(|(k, _)| !known.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            let entry = ArtifactEntry {
                name: get_str("name")?,
                file: get_str("file")?,
                dtype: get_str("dtype")?,
                kind: get_str("kind").unwrap_or_default(),
                model: get_str("model").unwrap_or_default(),
                dim: obj.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
                inputs: tensor_specs(obj.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: tensor_specs(obj.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
                param_layout: param_layout(obj.get("param_layout"))?,
                meta,
            };
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest (available: {})",
                self.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Entry for (model, kind, dtype tag), e.g. ("hmm", "nuts_step", "f32").
    pub fn find(&self, model: &str, kind: &str, dtype: &str) -> Result<&ArtifactEntry> {
        self.get(&format!("{model}_{kind}_{dtype}"))
    }

    pub fn models(&self) -> Vec<String> {
        let mut models: Vec<String> = self
            .entries
            .values()
            .map(|e| e.model.clone())
            .filter(|m| !m.is_empty())
            .collect();
        models.sort();
        models.dedup();
        models
    }
}
