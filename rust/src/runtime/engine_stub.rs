//! Offline stub for the PJRT engine, compiled when the `pjrt` feature
//! is **off** (the default).  API-identical to `engine.rs` so the
//! coordinator, harness, examples and tests build without the `xla`
//! bindings: the manifest loads normally (native workloads need its
//! static shapes), but anything touching a device — compiling an
//! executable, uploading a buffer, reading a literal — returns a
//! descriptive error.

use std::rc::Rc;

use anyhow::{bail, Result};

use super::manifest::{ArtifactEntry, DType, Manifest};

const NO_PJRT: &str = "fugue was built without the `pjrt` feature; \
     rebuild with `cargo build --features pjrt` (requires the xla \
     bindings and libxla — see README.md)";

/// Opaque placeholder for a device buffer (never constructible: every
/// path that would produce one errors first).
pub struct PjrtBuffer {
    _private: (),
}

/// Opaque placeholder for a host literal.
pub struct Literal {
    _private: (),
}

/// Host-side tensor for marshalling executable inputs.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    F64(Vec<f64>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    /// Cast an f64 slice to the dtype the artifact expects.
    pub fn from_f64(data: &[f64], shape: &[usize], dtype: DType) -> Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => {
                HostTensor::F32(data.iter().map(|&v| v as f32).collect(), shape.to_vec())
            }
            DType::F64 => HostTensor::F64(data.to_vec(), shape.to_vec()),
            DType::I32 => {
                HostTensor::I32(data.iter().map(|&v| v as i32).collect(), shape.to_vec())
            }
            other => bail!("from_f64: unsupported target dtype {other:?}"),
        })
    }
}

/// A compiled artifact plus its manifest entry (stub: never built).
pub struct Executable {
    pub entry: ArtifactEntry,
}

impl Executable {
    pub fn run_buffers(&self, _args: &[&PjrtBuffer]) -> Result<Vec<Literal>> {
        bail!(NO_PJRT)
    }
}

/// Manifest-only engine: artifact metadata without a PJRT client.
pub struct Engine {
    pub manifest: Manifest,
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        Ok(Engine { manifest })
    }

    /// Load + compile an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        bail!("artifact '{}': {}", name, NO_PJRT)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, _t: &HostTensor) -> Result<PjrtBuffer> {
        bail!(NO_PJRT)
    }
}

/// Read a literal's contents as f64 regardless of its element type.
pub fn literal_to_f64(_lit: &Literal) -> Result<Vec<f64>> {
    bail!(NO_PJRT)
}

/// Read a scalar literal as f64.
pub fn literal_scalar_f64(_lit: &Literal) -> Result<f64> {
    bail!(NO_PJRT)
}
