//! Offline stubs for the PJRT artifact handles, compiled when the
//! `pjrt` feature is **off** (the default).  Constructors return a
//! descriptive error, so these types can never actually exist at
//! runtime — they only satisfy the type graph of the coordinator,
//! harness, benches and examples.

use anyhow::{bail, Result};

use crate::mcmc::{Potential, Transition};

use super::engine::{Engine, HostTensor};
use super::manifest::ArtifactEntry;

const NO_PJRT: &str = "fugue was built without the `pjrt` feature; \
     rebuild with `cargo build --features pjrt` (see README.md)";

/// Fused end-to-end NUTS transition (stub: never constructible).
pub struct NutsStep {
    pub dim: usize,
    /// PJRT dispatches so far (one per draw — the benchmark's point).
    pub dispatches: u64,
}

impl NutsStep {
    pub fn new(_engine: &Engine, name: &str, _data: &[HostTensor]) -> Result<NutsStep> {
        bail!("artifact '{}': {}", name, NO_PJRT)
    }

    pub fn entry(&self) -> &ArtifactEntry {
        unreachable!("stub NutsStep cannot be constructed")
    }

    pub fn step(
        &mut self,
        _key: [u32; 2],
        _z: &[f64],
        _step_size: f64,
        _inv_mass: &[f64],
    ) -> Result<Transition> {
        bail!(NO_PJRT)
    }

    pub fn step_vmap(
        &mut self,
        _keys: &[[u32; 2]],
        _zs: &[f64],
        _step_sizes: &[f64],
        _inv_masses: &[f64],
    ) -> Result<Vec<Transition>> {
        bail!(NO_PJRT)
    }
}

/// Pyro-architecture comparator (stub: never constructible).
pub struct PjrtPotential {
    pub dim: usize,
    evals: u64,
}

impl PjrtPotential {
    pub fn new(_engine: &Engine, name: &str, _data: &[HostTensor]) -> Result<PjrtPotential> {
        bail!("artifact '{}': {}", name, NO_PJRT)
    }

    pub fn eval(&mut self, _z: &[f64], _grad: &mut [f64]) -> Result<f64> {
        bail!(NO_PJRT)
    }
}

impl Potential for PjrtPotential {
    fn dim(&self) -> usize {
        self.dim
    }

    fn value_and_grad(&mut self, _z: &[f64], _grad: &mut [f64]) -> f64 {
        unreachable!("stub PjrtPotential cannot be constructed")
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}
