//! PJRT engine: CPU client + per-artifact compile cache.
//!
//! HLO **text** is the interchange format (jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects in proto form; the
//! text parser reassigns ids — see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactEntry, DType, Manifest};

/// Host-side tensor for marshalling executable inputs.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    F64(Vec<f64>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    /// Cast an f64 slice to the dtype the artifact expects.
    pub fn from_f64(data: &[f64], shape: &[usize], dtype: DType) -> Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => {
                HostTensor::F32(data.iter().map(|&v| v as f32).collect(), shape.to_vec())
            }
            DType::F64 => HostTensor::F64(data.to_vec(), shape.to_vec()),
            DType::I32 => {
                HostTensor::I32(data.iter().map(|&v| v as i32).collect(), shape.to_vec())
            }
            other => bail!("from_f64: unsupported target dtype {other:?}"),
        })
    }

    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match self {
            HostTensor::F32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
            HostTensor::F64(d, s) => client.buffer_from_host_buffer(d, s, None)?,
            HostTensor::I32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
            HostTensor::U32(d, s) => client.buffer_from_host_buffer(d, s, None)?,
        };
        Ok(buf)
    }
}

/// A compiled artifact plus its manifest entry.
pub struct Executable {
    pub entry: ArtifactEntry,
    pub exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with device-resident buffers; returns the decomposed
    /// output tuple as host literals.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute_b(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// PJRT CPU client + compile cache, shared by all handles.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get(name)?.clone();
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exec = Rc::new(Executable { entry, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        t.to_buffer(&self.client)
    }
}

/// Read a literal's contents as f64 regardless of its element type.
pub fn literal_to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    let ty = lit.ty()?;
    Ok(match ty {
        xla::ElementType::F32 => lit.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect(),
        xla::ElementType::F64 => lit.to_vec::<f64>()?,
        xla::ElementType::S32 => lit.to_vec::<i32>()?.into_iter().map(|v| v as f64).collect(),
        xla::ElementType::Pred => {
            // PRED literals reject typed reads; convert to S32 first
            let conv = lit.convert(xla::PrimitiveType::S32)?;
            conv.to_vec::<i32>()?.into_iter().map(|v| v as f64).collect()
        }
        other => bail!("literal_to_f64: unsupported element type {other:?}"),
    })
}

/// Read a scalar literal as f64.
pub fn literal_scalar_f64(lit: &xla::Literal) -> Result<f64> {
    Ok(literal_to_f64(lit)?[0])
}
