//! Posterior summaries: per-parameter mean/sd/quantiles + ESS + R-hat,
//! with manifest-driven site labels.

use crate::diagnostics::ess::{effective_sample_size, split_rhat};
use crate::runtime::manifest::ParamSpan;

#[derive(Debug, Clone)]
pub struct ParamSummary {
    pub name: String,
    pub mean: f64,
    pub sd: f64,
    pub q05: f64,
    pub q50: f64,
    pub q95: f64,
    pub ess: f64,
    pub rhat: f64,
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// `chains[c]` is a (draws x dim) row-major matrix for chain c.
/// `layout` labels flat indices with site names (may be empty).
pub fn summarize(chains: &[Vec<f64>], dim: usize, layout: &[ParamSpan]) -> Vec<ParamSummary> {
    let label = |d: usize| -> String {
        for span in layout {
            if d >= span.offset && d < span.offset + span.size {
                if span.size == 1 {
                    return span.site.clone();
                }
                return format!("{}[{}]", span.site, d - span.offset);
            }
        }
        format!("z[{d}]")
    };

    (0..dim)
        .map(|d| {
            let per_chain: Vec<Vec<f64>> = chains
                .iter()
                .map(|c| c.chunks(dim).map(|row| row[d]).collect())
                .collect();
            let all: Vec<f64> = per_chain.iter().flatten().copied().collect();
            let n = all.len() as f64;
            let mean = all.iter().sum::<f64>() / n;
            let sd = (all.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0)).sqrt();
            let mut sorted = all;
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ParamSummary {
                name: label(d),
                mean,
                sd,
                q05: quantile(&sorted, 0.05),
                q50: quantile(&sorted, 0.50),
                q95: quantile(&sorted, 0.95),
                ess: effective_sample_size(&per_chain),
                rhat: split_rhat(&per_chain),
            }
        })
        .collect()
}

/// Render a summary table (plain text).
pub fn render_table(rows: &[ParamSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8} {:>6}\n",
        "param", "mean", "sd", "5%", "50%", "95%", "ess", "rhat"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>8.0} {:>6.3}\n",
            r.name, r.mean, r.sd, r.q05, r.q50, r.q95, r.ess, r.rhat
        ));
    }
    out
}

/// Cross-chain split-R̂ per parameter over pooled multi-chain results:
/// `chains[c]` is chain c's (draws x dim) row-major sample matrix (the
/// layout of [`crate::coordinator::ChainResult::samples`]).
pub fn cross_chain_rhat(chains: &[Vec<f64>], dim: usize) -> Vec<f64> {
    (0..dim)
        .map(|d| {
            let per_chain: Vec<Vec<f64>> = chains
                .iter()
                .map(|c| c.chunks(dim).map(|row| row[d]).collect())
                .collect();
            split_rhat(&per_chain)
        })
        .collect()
}

/// Worst (largest) cross-chain split-R̂ across parameters — the single
/// convergence number the bench harness and CLI report.
pub fn max_cross_chain_rhat(chains: &[Vec<f64>], dim: usize) -> f64 {
    cross_chain_rhat(chains, dim)
        .into_iter()
        .filter(|r| r.is_finite())
        .fold(f64::NAN, f64::max)
}

/// Min ESS across parameters (the Fig 2b denominator).
pub fn min_ess(rows: &[ParamSummary]) -> f64 {
    rows.iter().map(|r| r.ess).fold(f64::INFINITY, f64::min)
}

/// Mean ESS across parameters (footnote 6 reports averages).
pub fn mean_ess(rows: &[ParamSummary]) -> f64 {
    rows.iter().map(|r| r.ess).sum::<f64>() / rows.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn cross_chain_rhat_flags_disagreeing_chains() {
        let mut rng = Rng::new(1);
        let dim = 2;
        let draws = 1000;
        let mk = |rng: &mut Rng, shift: f64| -> Vec<f64> {
            (0..draws)
                .flat_map(|_| vec![rng.normal() + shift, rng.normal()])
                .collect()
        };
        let good = [mk(&mut rng, 0.0), mk(&mut rng, 0.0), mk(&mut rng, 0.0)];
        let rhats = cross_chain_rhat(&good, dim);
        assert!(rhats.iter().all(|r| (r - 1.0).abs() < 0.02), "{rhats:?}");

        let bad = [mk(&mut rng, 0.0), mk(&mut rng, 4.0)];
        let rhats = cross_chain_rhat(&bad, dim);
        assert!(rhats[0] > 1.5, "first param should diverge: {rhats:?}");
        assert!((rhats[1] - 1.0).abs() < 0.05, "{rhats:?}");
        assert!(max_cross_chain_rhat(&bad, dim) > 1.5);
    }

    #[test]
    fn summary_of_known_gaussian() {
        let mut rng = Rng::new(0);
        let dim = 2;
        let draws = 4000;
        let chain: Vec<f64> = (0..draws)
            .flat_map(|_| vec![rng.normal() * 2.0 + 1.0, rng.normal()])
            .collect();
        let rows = summarize(&[chain], dim, &[]);
        assert!((rows[0].mean - 1.0).abs() < 0.15);
        assert!((rows[0].sd - 2.0).abs() < 0.15);
        assert!((rows[1].mean).abs() < 0.1);
        assert!((rows[1].q50 - rows[1].mean).abs() < 0.1);
        assert!(rows[0].ess > 3000.0);
    }
}
