//! Effective sample size + split R-hat (Stan / BDA3 reference
//! formulation).
//!
//! Input layout: `chains[c]` is chain c's draws of ONE scalar parameter.
//! Chains are split in half internally (so m = 2 * num_chains), which
//! makes the estimators valid for a single chain too.

/// Autocovariance at lags 0..max_lag (biased, divided by n).
fn autocovariance(x: &[f64], max_lag: usize) -> Vec<f64> {
    let n = x.len();
    let mean = x.iter().sum::<f64>() / n as f64;
    let mut acov = Vec::with_capacity(max_lag + 1);
    for t in 0..=max_lag {
        let mut s = 0.0;
        for i in 0..n - t {
            s += (x[i] - mean) * (x[i + t] - mean);
        }
        acov.push(s / n as f64);
    }
    acov
}

fn split(chains: &[Vec<f64>]) -> Vec<&[f64]> {
    let mut halves = Vec::with_capacity(chains.len() * 2);
    for c in chains {
        let h = c.len() / 2;
        halves.push(&c[..h]);
        halves.push(&c[h..2 * h]);
    }
    halves
}

/// Split R-hat (potential scale reduction factor).
pub fn split_rhat(chains: &[Vec<f64>]) -> f64 {
    let halves = split(chains);
    let m = halves.len() as f64;
    let n = halves[0].len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let means: Vec<f64> = halves
        .iter()
        .map(|h| h.iter().sum::<f64>() / n)
        .collect();
    let grand = means.iter().sum::<f64>() / m;
    let b = n / (m - 1.0) * means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>();
    let w = halves
        .iter()
        .zip(&means)
        .map(|(h, mu)| h.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / (n - 1.0))
        .sum::<f64>()
        / m;
    if w <= 0.0 {
        return f64::NAN;
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

/// Effective sample size with Geyer's initial monotone positive
/// sequence over the combined-chain correlogram.
pub fn effective_sample_size(chains: &[Vec<f64>]) -> f64 {
    let halves = split(chains);
    let m = halves.len() as f64;
    let n = halves[0].len();
    if n < 4 {
        return f64::NAN;
    }
    let max_lag = n - 1;
    let acovs: Vec<Vec<f64>> = halves
        .iter()
        .map(|h| autocovariance(h, max_lag))
        .collect();
    // within-chain variance (unbiased) and var_plus
    let w: f64 = acovs.iter().map(|a| a[0] * n as f64 / (n as f64 - 1.0)).sum::<f64>() / m;
    let means: Vec<f64> = halves
        .iter()
        .map(|h| h.iter().sum::<f64>() / n as f64)
        .collect();
    let grand = means.iter().sum::<f64>() / m;
    let b_over_n = if halves.len() > 1 {
        means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>() / (m - 1.0)
    } else {
        0.0
    };
    let var_plus = w * (n as f64 - 1.0) / n as f64 + b_over_n;
    if var_plus <= 0.0 {
        return f64::NAN;
    }

    // rho_t = 1 - (W - mean acov_t) / var_plus
    let mut rho = vec![0.0; max_lag + 1];
    for (t, r) in rho.iter_mut().enumerate() {
        let mean_acov: f64 = acovs.iter().map(|a| a[t]).sum::<f64>() / m;
        *r = 1.0 - (w - mean_acov) / var_plus;
    }

    // Geyer: sum consecutive pairs while positive, enforce monotone
    // non-increasing pair sums.
    let mut sum_rho = 0.0;
    let mut prev_pair = f64::INFINITY;
    let mut t = 1;
    while t + 1 <= max_lag {
        let mut pair = rho[t] + rho[t + 1];
        if pair < 0.0 {
            break;
        }
        if pair > prev_pair {
            pair = prev_pair;
        }
        sum_rho += pair;
        prev_pair = pair;
        t += 2;
    }
    let tau = 1.0 + 2.0 * sum_rho;
    let total = m * n as f64;
    (total / tau).min(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn ar1(rng: &mut Rng, n: usize, rho: f64) -> Vec<f64> {
        let mut x = vec![0.0; n];
        let sd = (1.0 - rho * rho).sqrt();
        for i in 1..n {
            x[i] = rho * x[i - 1] + sd * rng.normal();
        }
        x
    }

    #[test]
    fn iid_chain_ess_near_n() {
        let mut rng = Rng::new(0);
        let chain: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let ess = effective_sample_size(&[chain]);
        assert!(ess > 3000.0 && ess <= 4000.0, "ess {ess}");
    }

    #[test]
    fn ar1_ess_matches_analytic() {
        // ESS/N -> (1-rho)/(1+rho) for AR(1)
        let mut rng = Rng::new(1);
        let rho = 0.7;
        let n = 20_000;
        let chain = ar1(&mut rng, n, rho);
        let ess = effective_sample_size(&[chain]);
        let expect = n as f64 * (1.0 - rho) / (1.0 + rho);
        assert!(
            (ess - expect).abs() < 0.25 * expect,
            "ess {ess} vs analytic {expect}"
        );
    }

    #[test]
    fn rhat_near_one_for_same_distribution() {
        let mut rng = Rng::new(2);
        let chains: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..2000).map(|_| rng.normal()).collect())
            .collect();
        let r = split_rhat(&chains);
        assert!((r - 1.0).abs() < 0.02, "rhat {r}");
    }

    #[test]
    fn rhat_detects_divergent_means() {
        let mut rng = Rng::new(3);
        let a: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..1000).map(|_| rng.normal() + 5.0).collect();
        let r = split_rhat(&[a, b]);
        assert!(r > 2.0, "rhat {r}");
    }

    #[test]
    fn anticorrelated_chain_ess_exceeds_n() {
        // ESS can exceed N for negatively autocorrelated chains, but is
        // clamped to total draws by our implementation.
        let mut rng = Rng::new(4);
        let chain = ar1(&mut rng, 8000, -0.5);
        let ess = effective_sample_size(&[chain]);
        assert!(ess <= 8000.0 + 1e-9);
        assert!(ess > 7000.0, "ess {ess}");
    }
}
