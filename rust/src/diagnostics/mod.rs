//! Convergence diagnostics: split-chain R-hat and effective sample size
//! (Geyer initial monotone sequence), following Stan's reference
//! implementations — these produce the "time per effective sample" axis
//! of Fig 2b and the ESS counts of footnote 6.

pub mod ess;
pub mod summary;

pub use ess::{effective_sample_size, split_rhat};
pub use summary::{cross_chain_rhat, max_cross_chain_rhat, summarize, ParamSummary};
