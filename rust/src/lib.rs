// `std::simd` micro-lane kernels (autodiff/batch.rs) are opt-in and
// nightly-only; the default build uses unrolled scalar kernels.
#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # fugue — composable effects + end-to-end-compiled iterative NUTS
//!
//! Reproduction of *"Composable Effects for Flexible and Accelerated
//! Probabilistic Programming in NumPyro"* (Phan, Pradhan & Jankowiak,
//! 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, Python)** — the paper's effect-handler PPL and
//!   the iterative NUTS transition (Appendix A, Algorithm 2) are lowered
//!   once by `python/compile/aot.py` into `artifacts/*.hlo.txt`.
//! * **L3 (this crate)** — a self-contained inference coordinator that
//!   loads the artifacts through PJRT ([`runtime`]), runs multi-chain
//!   NUTS with Stan-style warmup adaptation ([`coordinator`]), computes
//!   convergence diagnostics ([`diagnostics`]), and regenerates every
//!   table and figure of the paper's evaluation ([`harness`]).
//!
//! The crate also contains complete *native* comparators used by the
//! benchmarks (DESIGN.md §3): a tape-based reverse-mode autodiff
//! ([`autodiff`], the Stan analogue), a Rust distribution/transform
//! library ([`ppl`]), Table 1's effect handlers over a Rust model trait
//! ([`effects`]), and pure-Rust recursive + iterative NUTS ([`mcmc`]).
//!
//! The [`compile`] module closes the loop between the two halves: it
//! compiles any effect-handler program (`sample`/`observe` only — no
//! hand-written density or gradient) into a differentiable
//! [`mcmc::Potential`] via a trace/condition/transform/differentiate
//! pipeline, so the native NUTS engine samples arbitrary models, not
//! just the three hand-fused benchmarks.  See `ARCHITECTURE.md` for the
//! paper-to-module map and the compiler dataflow.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `fugue` binary is self-contained.

pub mod autodiff;
pub mod cli;
pub mod compile;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diagnostics;
pub mod effects;
pub mod error;
pub mod harness;
pub mod mcmc;
pub mod models;
pub mod obs;
pub mod ppl;
pub mod rng;
pub mod runtime;
pub mod svi;
pub mod util;
