//! The [`Sampler`] abstraction: one NUTS transition, whatever the
//! backend.  The three implementations are the three architectures of
//! Table 2a (DESIGN.md §3):
//!
//! * [`FusedSampler`] — NumPyro architecture: one PJRT dispatch per draw
//!   (the whole Algorithm-2 tree compiled end-to-end).
//! * [`NativeSampler`] over a native potential — Stan architecture:
//!   compiled native code, no dispatch boundary at all.
//! * [`NativeSampler`] over [`crate::runtime::PjrtPotential`] with the
//!   recursive tree — Pyro architecture: host-side tree, one compiled
//!   dispatch per leapfrog.

use anyhow::Result;

use crate::mcmc::{nuts_iterative, nuts_recursive, Potential, Transition};
use crate::rng::Rng;
use crate::runtime::NutsStep;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeAlgorithm {
    /// Algorithm 1 (recursive BuildTree)
    Recursive,
    /// Algorithm 2 (IterativeBuildTree)
    Iterative,
}

pub trait Sampler {
    fn dim(&self) -> usize;

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition>;

    /// Compiled-callable dispatches so far (for the Table 2a narrative).
    fn dispatches(&self) -> u64 {
        0
    }
}

impl Sampler for Box<dyn Sampler> {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition> {
        (**self).draw(rng, z, step_size, inv_mass)
    }

    fn dispatches(&self) -> u64 {
        (**self).dispatches()
    }
}

/// NumPyro architecture: the fused `nuts_step` artifact.
pub struct FusedSampler {
    pub step: NutsStep,
}

impl FusedSampler {
    pub fn new(step: NutsStep) -> Self {
        FusedSampler { step }
    }
}

impl Sampler for FusedSampler {
    fn dim(&self) -> usize {
        self.step.dim
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition> {
        let key = [
            (rng.next_u64() >> 32) as u32,
            (rng.next_u64() & 0xFFFF_FFFF) as u32,
        ];
        self.step.step(key, z, step_size, inv_mass)
    }

    fn dispatches(&self) -> u64 {
        self.step.dispatches
    }
}

/// Host-side tree building over any [`Potential`] (native autodiff =
/// Stan architecture; PJRT potential = Pyro architecture).
///
/// For the iterative algorithm the sampler owns a persistent
/// [`nuts_iterative::TreeWorkspace`], so its per-draw hot path is
/// allocation-free (one proposal-vector allocation per draw to fill the
/// returned [`Transition`]).
pub struct NativeSampler<P: Potential> {
    pub potential: P,
    pub algorithm: TreeAlgorithm,
    pub max_tree_depth: u32,
    workspace: Option<nuts_iterative::TreeWorkspace>,
}

impl<P: Potential> NativeSampler<P> {
    pub fn new(potential: P, algorithm: TreeAlgorithm, max_tree_depth: u32) -> Self {
        NativeSampler {
            potential,
            algorithm,
            max_tree_depth,
            workspace: None,
        }
    }
}

impl<P: Potential> Sampler for NativeSampler<P> {
    fn dim(&self) -> usize {
        self.potential.dim()
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition> {
        Ok(match self.algorithm {
            TreeAlgorithm::Recursive => nuts_recursive::draw(
                &mut self.potential,
                rng,
                z,
                step_size,
                inv_mass,
                self.max_tree_depth,
            ),
            TreeAlgorithm::Iterative => {
                let dim = self.potential.dim();
                let max_depth = self.max_tree_depth;
                // recreate the workspace if it was sized for a smaller
                // tree depth (max_tree_depth is a pub field) or another
                // dimension
                let stale = match &self.workspace {
                    Some(w) => w.dim() != dim || w.max_depth() < max_depth,
                    None => true,
                };
                if stale {
                    self.workspace = Some(nuts_iterative::TreeWorkspace::new(dim, max_depth));
                }
                let ws = self.workspace.as_mut().expect("workspace just ensured");
                nuts_iterative::draw_with(
                    &mut self.potential,
                    rng,
                    ws,
                    z,
                    step_size,
                    inv_mass,
                    max_depth,
                )
            }
        })
    }

    fn dispatches(&self) -> u64 {
        self.potential.num_evals()
    }
}
