//! Stan's three-phase warmup schedule: an initial fast interval (step
//! size only), doubling slow windows (mass-matrix estimation), and a
//! terminal fast interval.  Mirrors `python/compile/infer/mcmc.py`.

#[derive(Debug, Clone)]
pub struct WarmupSchedule {
    pub initial_fast: usize,
    pub slow_windows: Vec<usize>,
    pub terminal_fast: usize,
}

impl WarmupSchedule {
    pub fn build(num_warmup: usize) -> WarmupSchedule {
        if num_warmup < 20 {
            return WarmupSchedule {
                initial_fast: num_warmup,
                slow_windows: vec![],
                terminal_fast: 0,
            };
        }
        let initial = ((0.15 * num_warmup as f64) as usize).max(10);
        let terminal = ((0.10 * num_warmup as f64) as usize).max(10);
        let mut remaining = num_warmup - initial - terminal;
        let mut windows = Vec::new();
        let mut w = 25;
        while remaining > 0 {
            if remaining >= 3 * w {
                windows.push(w);
                remaining -= w;
                w *= 2;
            } else {
                windows.push(remaining);
                remaining = 0;
            }
        }
        WarmupSchedule {
            initial_fast: initial,
            slow_windows: windows,
            terminal_fast: terminal,
        }
    }

    pub fn total(&self) -> usize {
        self.initial_fast + self.slow_windows.iter().sum::<usize>() + self.terminal_fast
    }

    /// Iteration indices (within warmup) at which a slow window closes —
    /// i.e. refresh the mass matrix and restart dual averaging.
    pub fn window_closes(&self) -> Vec<usize> {
        let mut closes = Vec::new();
        let mut pos = self.initial_fast;
        for w in &self.slow_windows {
            pos += w;
            closes.push(pos - 1);
        }
        closes
    }

    /// Is iteration `i` inside a slow (mass-estimation) window?
    pub fn in_slow(&self, i: usize) -> bool {
        let slow_start = self.initial_fast;
        let slow_end = self.total() - self.terminal_fast;
        i >= slow_start && i < slow_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_exactly() {
        for &n in &[20, 50, 100, 500, 1000, 1234] {
            let s = WarmupSchedule::build(n);
            assert_eq!(s.total(), n, "n={n}");
        }
    }

    #[test]
    fn windows_double() {
        let s = WarmupSchedule::build(1000);
        assert_eq!(s.initial_fast, 150);
        assert_eq!(s.terminal_fast, 100);
        // doubling windows, last absorbs the remainder
        let w = &s.slow_windows;
        assert!(w.len() >= 3);
        for i in 1..w.len() - 1 {
            assert_eq!(w[i], 2 * w[i - 1]);
        }
    }

    #[test]
    fn tiny_warmup_is_all_fast() {
        let s = WarmupSchedule::build(10);
        assert_eq!(s.initial_fast, 10);
        assert!(s.slow_windows.is_empty());
        assert!(s.window_closes().is_empty());
    }

    #[test]
    fn window_closes_inside_slow_phase() {
        let s = WarmupSchedule::build(400);
        for c in s.window_closes() {
            assert!(s.in_slow(c));
        }
    }
}
