//! Chain runner: warmup (dual averaging + Welford windows) then
//! sampling, with per-phase timing and leapfrog accounting — the
//! numbers Table 2a and Fig 2b are computed from.

use anyhow::Result;

use crate::coordinator::sampler::Sampler;
use crate::coordinator::warmup::WarmupSchedule;
use crate::mcmc::{DualAverage, Welford};
use crate::obs::{Phase, Recorder, SpanKind};
use crate::rng::Rng;

#[derive(Debug, Clone)]
pub struct NutsOptions {
    pub num_warmup: usize,
    pub num_samples: usize,
    pub target_accept: f64,
    pub init_step_size: f64,
    /// Some(eps): skip step-size adaptation (the paper fixes eps for the
    /// COVTYPE benchmark and for Pyro's HMM runs).
    pub fixed_step_size: Option<f64>,
    pub adapt_mass: bool,
    pub seed: u64,
}

impl Default for NutsOptions {
    fn default() -> Self {
        NutsOptions {
            num_warmup: 500,
            num_samples: 500,
            target_accept: 0.8,
            init_step_size: 0.1,
            fixed_step_size: None,
            adapt_mass: true,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ChainStats {
    pub accept_prob: Vec<f64>,
    pub num_leapfrog: Vec<u32>,
    pub potential: Vec<f64>,
    pub diverging: Vec<bool>,
    pub depth: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct ChainResult {
    /// (num_samples x dim) row-major
    pub samples: Vec<f64>,
    pub dim: usize,
    pub stats: ChainStats,
    pub step_size: f64,
    pub inv_mass: Vec<f64>,
    pub warmup_secs: f64,
    pub sample_secs: f64,
    /// leapfrogs during the sampling phase only
    pub sample_leapfrogs: u64,
    pub total_leapfrogs: u64,
    pub divergences: u64,
    /// Poisoned draws contained by the fault layer: the trajectory's
    /// starting energy was non-finite, no leapfrog was taken, and the
    /// chain stayed at its last good position (see
    /// [`crate::mcmc::DrawStats::poisoned`]).  Always 0 on a healthy
    /// run; nonzero values are the per-chain health signal the
    /// diagnostics surface.
    pub quarantines: u64,
}

impl ChainResult {
    /// Time per leapfrog during sampling — Table 2a's metric.
    pub fn ms_per_leapfrog(&self) -> f64 {
        1e3 * self.sample_secs / self.sample_leapfrogs.max(1) as f64
    }
}

/// The complete resumable state of one chain between draws: position,
/// RNG stream (including the cached Box-Muller spare), warmup
/// adaptation (dual averaging + Welford window), accumulated
/// samples/statistics and counters.  Draw boundaries are full
/// checkpoints — the tree workspaces are pure per-draw scratch
/// re-initialized from `z` each draw — so serializing a cursor
/// (`crate::coordinator::checkpoint`) and resuming continues the chain
/// **bitwise-identically**.
#[derive(Debug, Clone)]
pub struct ChainCursor {
    /// Index of the next draw (0-based over warmup + sampling).
    pub i: usize,
    pub z: Vec<f64>,
    pub rng: Rng,
    pub da: DualAverage,
    pub welford: Welford,
    pub step_size: f64,
    pub inv_mass: Vec<f64>,
    pub stats: ChainStats,
    pub samples: Vec<f64>,
    pub sample_leapfrogs: u64,
    pub total_leapfrogs: u64,
    pub divergences: u64,
    pub quarantines: u64,
}

impl ChainCursor {
    /// Fresh cursor at draw 0.  `opts.seed` must already be the
    /// *chain-level* seed (i.e. [`chain_start`]'s derived options).
    pub fn new(init_z: &[f64], opts: &NutsOptions) -> ChainCursor {
        let dim = init_z.len();
        let total = opts.num_warmup + opts.num_samples;
        let mut stats = ChainStats::default();
        stats.accept_prob.reserve(total);
        stats.num_leapfrog.reserve(total);
        stats.potential.reserve(total);
        stats.diverging.reserve(total);
        stats.depth.reserve(total);
        ChainCursor {
            i: 0,
            z: init_z.to_vec(),
            rng: Rng::new(opts.seed),
            da: DualAverage::new(
                opts.fixed_step_size.unwrap_or(opts.init_step_size),
                opts.target_accept,
            ),
            welford: Welford::new(dim),
            step_size: opts.fixed_step_size.unwrap_or(opts.init_step_size),
            inv_mass: vec![1.0; dim],
            stats,
            samples: Vec::with_capacity(opts.num_samples * dim),
            sample_leapfrogs: 0,
            total_leapfrogs: 0,
            divergences: 0,
            quarantines: 0,
        }
    }

    /// Package the (possibly partial) accumulated state as a
    /// [`ChainResult`].  Timing is supplied by the caller — wall-clock
    /// is outside the bitwise-resume contract.
    pub fn into_result(self, warmup_secs: f64, sample_secs: f64) -> ChainResult {
        let dim = self.inv_mass.len();
        ChainResult {
            samples: self.samples,
            dim,
            stats: self.stats,
            step_size: self.step_size,
            inv_mass: self.inv_mass,
            warmup_secs,
            sample_secs,
            sample_leapfrogs: self.sample_leapfrogs,
            total_leapfrogs: self.total_leapfrogs,
            divergences: self.divergences,
            quarantines: self.quarantines,
        }
    }
}

/// Advance one draw: the loop body of [`run_chain`], factored out so
/// checkpointed/budgeted runners replay the **exact** statement order
/// (and hence stay bitwise-identical to an uninterrupted run).
///
/// Containment: a poisoned transition (non-finite starting energy —
/// `diverging` with zero leapfrogs) is counted in `quarantines`, and
/// its `accept_prob`/position are kept **out** of the dual-averaging
/// and Welford feeds so one faulted evaluation cannot corrupt warmup
/// adaptation; the chain holds its last good position (the sampler
/// already proposes the unchanged start).
pub(crate) fn advance_chain<S: Sampler>(
    sampler: &mut S,
    cur: &mut ChainCursor,
    opts: &NutsOptions,
    schedule: &WarmupSchedule,
    closes: &[usize],
) -> Result<()> {
    let i = cur.i;
    let tr = sampler.draw(&mut cur.rng, &cur.z, cur.step_size, &cur.inv_mass)?;
    let poisoned = tr.diverging && tr.num_leapfrog == 0;
    cur.z.copy_from_slice(&tr.z);
    cur.total_leapfrogs += tr.num_leapfrog as u64;
    if tr.diverging {
        cur.divergences += 1;
    }
    if poisoned {
        cur.quarantines += 1;
    }
    cur.stats.accept_prob.push(tr.accept_prob);
    cur.stats.num_leapfrog.push(tr.num_leapfrog);
    cur.stats.potential.push(tr.potential);
    cur.stats.diverging.push(tr.diverging);
    cur.stats.depth.push(tr.depth);

    if i < opts.num_warmup {
        if opts.fixed_step_size.is_none() {
            if !poisoned {
                cur.da.update(tr.accept_prob);
            }
            cur.step_size = cur.da.step_size();
        }
        if opts.adapt_mass && schedule.in_slow(i) {
            if !poisoned {
                cur.welford.update(&cur.z);
            }
            if closes.contains(&i) {
                cur.inv_mass = cur.welford.regularized_variance();
                cur.welford.reset();
                if opts.fixed_step_size.is_none() {
                    cur.da.restart(cur.da.step_size());
                    cur.step_size = cur.da.step_size();
                }
            }
        }
        if i + 1 == opts.num_warmup && opts.fixed_step_size.is_none() {
            cur.step_size = cur.da.final_step_size();
        }
    } else {
        cur.samples.extend_from_slice(&cur.z);
        cur.sample_leapfrogs += tr.num_leapfrog as u64;
    }
    cur.i = i + 1;
    // flight recorder: trace the (already updated) step size; pure
    // observation, after all adaptation decisions for this draw
    Recorder::global().record_step_size(cur.step_size);
    Ok(())
}

/// Run one chain: Stan-style warmup + sampling.
pub fn run_chain<S: Sampler>(
    sampler: &mut S,
    init_z: &[f64],
    opts: &NutsOptions,
) -> Result<ChainResult> {
    let dim = sampler.dim();
    assert_eq!(init_z.len(), dim);
    let schedule = WarmupSchedule::build(opts.num_warmup);
    let closes = schedule.window_closes();
    let total = opts.num_warmup + opts.num_samples;

    let rec = Recorder::global();
    let mut cur = ChainCursor::new(init_z, opts);
    let t_warm = std::time::Instant::now();
    let mut warmup_secs = 0.0;
    rec.set_phase(if opts.num_warmup > 0 {
        Phase::Warmup
    } else {
        Phase::Sampling
    });
    while cur.i < total {
        advance_chain(sampler, &mut cur, opts, &schedule, &closes)?;
        if cur.i == opts.num_warmup {
            warmup_secs = t_warm.elapsed().as_secs_f64();
            rec.set_phase(Phase::Sampling);
        }
    }
    if opts.num_warmup == 0 {
        warmup_secs = 0.0;
    }
    let sample_secs = t_warm.elapsed().as_secs_f64() - warmup_secs;
    rec.add_span_secs(SpanKind::Warmup, warmup_secs);
    rec.add_span_secs(SpanKind::Sampling, sample_secs);
    Ok(cur.into_result(warmup_secs, sample_secs))
}

/// Deterministic per-chain start: chain `c` draws its uniform(-2,2)
/// initialization from the split stream `seed ^ (0xC0FFEE + c)` and
/// samples with seed `seed + 1 + c`.  Shared by the sequential
/// [`run_chains`] and the parallel
/// [`crate::coordinator::ParallelChainRunner`], so the two produce
/// bitwise-identical chains for the same options.
pub fn chain_start(dim: usize, opts: &NutsOptions, c: usize) -> (Vec<f64>, NutsOptions) {
    let mut init_rng = Rng::new(opts.seed ^ (0xC0FFEE + c as u64));
    let init_z: Vec<f64> = (0..dim).map(|_| init_rng.uniform_in(-2.0, 2.0)).collect();
    let mut o = opts.clone();
    o.seed = opts.seed.wrapping_add(1 + c as u64);
    (init_z, o)
}

/// Run several chains sequentially with derived seeds and random
/// uniform(-2,2) initializations (NumPyro's init_to_uniform).
pub fn run_chains<S: Sampler>(
    sampler: &mut S,
    num_chains: usize,
    opts: &NutsOptions,
) -> Result<Vec<ChainResult>> {
    let dim = sampler.dim();
    let mut results = Vec::with_capacity(num_chains);
    for c in 0..num_chains {
        let (init_z, o) = chain_start(dim, opts, c);
        results.push(run_chain(sampler, &init_z, &o)?);
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampler::{NativeSampler, TreeAlgorithm};
    use crate::mcmc::Potential;

    /// Standard 2-d Gaussian potential.
    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    fn check_gaussian(algorithm: TreeAlgorithm) {
        let mut sampler = NativeSampler::new(Gauss, algorithm, 10);
        let opts = NutsOptions {
            num_warmup: 300,
            num_samples: 1500,
            seed: 42,
            ..Default::default()
        };
        let res = run_chain(&mut sampler, &[1.0, -1.0], &opts).unwrap();
        let n = opts.num_samples as f64;
        for d in 0..2 {
            let mean: f64 = res.samples.chunks(2).map(|r| r[d]).sum::<f64>() / n;
            let var: f64 = res.samples.chunks(2).map(|r| (r[d] - mean).powi(2)).sum::<f64>() / n;
            assert!(mean.abs() < 0.15, "{algorithm:?} mean[{d}] {mean}");
            assert!((var - 1.0).abs() < 0.25, "{algorithm:?} var[{d}] {var}");
        }
        // adaptation reached a sensible step size and acceptance
        let accept: f64 = res.stats.accept_prob[300..].iter().sum::<f64>() / n;
        assert!(accept > 0.6, "{algorithm:?} accept {accept}");
    }

    #[test]
    fn iterative_samples_standard_gaussian() {
        check_gaussian(TreeAlgorithm::Iterative);
    }

    #[test]
    fn recursive_samples_standard_gaussian() {
        check_gaussian(TreeAlgorithm::Recursive);
    }
}
