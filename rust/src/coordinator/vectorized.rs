//! The vectorized chain engine: K chains sampled **in lock-step**
//! through the batched NUTS kernel ([`crate::mcmc::batch_nuts`]), with
//! one fused [`BatchPotential`] gradient evaluation shared by every
//! chain per leapfrog — the native reproduction of NumPyro's
//! `chain_method="vectorized"` (`vmap` over the sampler, paper E7).
//!
//! Each lane keeps its **own** warmup state — dual-averaged step size,
//! Welford mass-matrix window, RNG stream — updated by exactly the
//! same schedule as the sequential [`crate::coordinator::run_chain`]
//! loop, and every lane derives its seed/init from the shared
//! [`chain_start`].  Chain `k` of
//! a vectorized run is therefore **bitwise identical** to chain `k` of
//! a sequential or thread-parallel run with the same options (pinned by
//! this module's tests and `rust/tests/chain_methods.rs`): the three
//! [`ChainMethod`]s are pure execution strategies, invisible to the
//! model and to the statistics.
//!
//! The lane trade-off: per draw, every chain waits for the longest
//! lane's trajectory (masked lanes still occupy SIMD width), but each
//! leapfrog costs one batched evaluation instead of K scalar ones.
//! `fugue bench` quantifies the exchange as
//! `vectorized_speedup_vs_parallel` / `vectorized_speedup_vs_sequential`
//! per chain count in `BENCH_native.json`.

use anyhow::{bail, Result};

use crate::compile::{
    tiled_from_layout, BatchedCompiledModel, CompiledModel, EffModel, SiteLayout,
};
use crate::coordinator::chain::{
    chain_start, run_chains, ChainCursor, ChainResult, NutsOptions,
};
use crate::coordinator::parallel::run_compiled_chains_opt;
use crate::coordinator::sampler::{NativeSampler, TreeAlgorithm};
use crate::coordinator::warmup::WarmupSchedule;
use crate::mcmc::batch_nuts::{draw_batch, BatchTreeWorkspace};
use crate::mcmc::{auto_tile_width, BatchPotential, DrawStats, DualAverage, Welford};
use crate::obs::{Phase, Recorder, SpanKind};
use crate::rng::Rng;

/// Chain counts above this ride the tiled massive-lane engine
/// ([`crate::mcmc::TiledBatchPotential`]) instead of one K-wide
/// program: past this width the lane-minor arrays overflow L1/L2 and
/// tile-per-thread dispatch wins.  Purely an execution-strategy
/// switch — the tiled engine is bitwise-identical per lane
/// (`rust/tests/lane_scaling.rs`), so results do not depend on it.
pub const TILED_LANE_THRESHOLD: usize = 64;

/// Multi-chain execution strategy (NumPyro's `chain_method`):
/// same statistics, different schedulers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChainMethod {
    /// One chain after another on the calling thread.
    Sequential,
    /// One OS thread per chain ([`crate::coordinator::ParallelChainRunner`]).
    Parallel,
    /// All chains in lock-step through the batched NUTS kernel with a
    /// fused multi-lane potential ([`run_chains_vectorized`]).
    Vectorized,
}

impl ChainMethod {
    pub fn parse(s: &str) -> Result<ChainMethod> {
        Ok(match s {
            "sequential" => ChainMethod::Sequential,
            "parallel" => ChainMethod::Parallel,
            "vectorized" => ChainMethod::Vectorized,
            other => bail!("unknown chain method '{other}' (sequential|parallel|vectorized)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChainMethod::Sequential => "sequential",
            ChainMethod::Parallel => "parallel",
            ChainMethod::Vectorized => "vectorized",
        }
    }
}

/// Run `pot.lanes()` chains in lock-step through the batched NUTS
/// kernel: Stan-style warmup (per-lane dual averaging + Welford
/// windows) then sampling, mirroring the sequential [`run_chain`]
/// bookkeeping statement-for-statement per lane.
///
/// Returns one [`ChainResult`] per lane, in chain order.  The per-phase
/// wall-clock fields (`warmup_secs` / `sample_secs`) are shared across
/// lanes — the lanes advance together, so per-chain timing is the
/// engine timing.
///
/// [`run_chain`]: crate::coordinator::run_chain
pub fn run_chains_vectorized<BP: BatchPotential + ?Sized>(
    pot: &mut BP,
    opts: &NutsOptions,
    max_tree_depth: u32,
) -> Result<Vec<ChainResult>> {
    let dim = pot.dim();
    let l = pot.lanes();
    if l == 0 {
        return Ok(Vec::new());
    }
    // per-lane seeds/inits from the shared derivation — chain k here
    // IS chain k of run_chains / ParallelChainRunner
    let mut cursors: Vec<ChainCursor> = (0..l)
        .map(|k| {
            let (init_z, chain_opts) = chain_start(dim, opts, k);
            ChainCursor::new(&init_z, &chain_opts)
        })
        .collect();
    let (warmup_secs, sample_secs, _completed) = run_chains_vectorized_from(
        pot,
        opts,
        max_tree_depth,
        &mut cursors,
        None,
        0,
        &mut |_| Ok(()),
    )?;
    Ok(cursors
        .into_iter()
        .map(|c| c.into_result(warmup_secs, sample_secs))
        .collect())
}

/// Copy the lane-local working state back into the per-lane cursors —
/// called at checkpoint boundaries and on exit so a serialized cursor
/// set is always a complete draw-boundary snapshot.
#[allow(clippy::too_many_arguments)]
fn sync_cursors(
    cursors: &mut [ChainCursor],
    rngs: &[Rng],
    das: &[DualAverage],
    steps: &[f64],
    welfords: &[Welford],
    z: &[f64],
    inv_mass: &[f64],
    dim: usize,
) {
    let l = cursors.len();
    for (k, cur) in cursors.iter_mut().enumerate() {
        cur.rng = rngs[k].clone();
        cur.da = das[k].clone();
        cur.step_size = steps[k];
        cur.welford = welfords[k].clone();
        for i in 0..dim {
            cur.z[i] = z[i * l + k];
            cur.inv_mass[i] = inv_mass[i * l + k];
        }
    }
}

/// The resumable core of the vectorized engine: advance all lanes in
/// lock-step from the draw index the `cursors` are parked at (all lanes
/// share one index — the engine is lock-step by construction), with an
/// optional wall-clock `deadline` and a checkpoint `sink` invoked with
/// the synchronized cursor set every `checkpoint_every` draws
/// (0 = never).
///
/// Returns `(warmup_secs, sample_secs, completed)`; `completed` is
/// false when the deadline cut the run short — the cursors then hold a
/// complete draw-boundary snapshot ready to serialize and resume
/// bitwise-identically.
///
/// Containment mirrors the sequential
/// [`crate::coordinator::chain`] loop per lane: a poisoned lane
/// (non-finite starting energy — already masked to `eps = 0` inside
/// [`draw_batch`], so sibling lanes are untouched) counts a quarantine,
/// keeps its fault out of the dual-averaging/Welford feeds, and
/// restarts the next draw from its last good position (the unchanged
/// proposal).
#[allow(clippy::too_many_arguments)]
pub fn run_chains_vectorized_from<BP: BatchPotential + ?Sized>(
    pot: &mut BP,
    opts: &NutsOptions,
    max_tree_depth: u32,
    cursors: &mut [ChainCursor],
    deadline: Option<std::time::Instant>,
    checkpoint_every: usize,
    sink: &mut dyn FnMut(&[ChainCursor]) -> Result<()>,
) -> Result<(f64, f64, bool)> {
    let dim = pot.dim();
    let l = pot.lanes();
    assert_eq!(cursors.len(), l, "one cursor per lane");
    let schedule = WarmupSchedule::build(opts.num_warmup);
    let closes = schedule.window_closes();
    let total = opts.num_warmup + opts.num_samples;

    let i0 = cursors[0].i;
    debug_assert!(
        cursors.iter().all(|c| c.i == i0),
        "vectorized lanes must share one draw index"
    );

    // lane-local working state, loaded from the cursors
    let mut rngs: Vec<Rng> = cursors.iter().map(|c| c.rng.clone()).collect();
    let mut das: Vec<DualAverage> = cursors.iter().map(|c| c.da.clone()).collect();
    let mut steps: Vec<f64> = cursors.iter().map(|c| c.step_size).collect();
    let mut welfords: Vec<Welford> = cursors.iter().map(|c| c.welford.clone()).collect();
    let mut z = vec![0.0; dim * l];
    let mut inv_mass = vec![0.0; dim * l];
    for (k, cur) in cursors.iter().enumerate() {
        for i in 0..dim {
            z[i * l + k] = cur.z[i];
            inv_mass[i * l + k] = cur.inv_mass[i];
        }
    }

    let mut ws = BatchTreeWorkspace::new(dim, l, max_tree_depth);
    let mut draw_stats = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        l
    ];
    let mut zrow = vec![0.0; dim];

    let t_warm = std::time::Instant::now();
    let mut warmup_secs = 0.0;
    let mut completed = true;

    // flight recorder: pure observation of already-computed values —
    // never consumes RNG, never reorders sampler fp ops (bitwise gate
    // in rust/tests/observability.rs)
    let rec = Recorder::global();
    rec.set_phase(if i0 < opts.num_warmup {
        Phase::Warmup
    } else {
        Phase::Sampling
    });

    for i in i0..total {
        if let Some(d) = deadline {
            if std::time::Instant::now() >= d {
                completed = false;
                break;
            }
        }
        draw_batch(
            pot,
            &mut rngs,
            &mut ws,
            &z,
            &steps,
            &inv_mass,
            max_tree_depth,
            &mut draw_stats,
        );
        z.copy_from_slice(ws.proposal());
        for k in 0..l {
            let st = draw_stats[k];
            cursors[k].total_leapfrogs += st.num_leapfrog as u64;
            if st.diverging {
                cursors[k].divergences += 1;
            }
            if st.poisoned {
                cursors[k].quarantines += 1;
            }
            cursors[k].stats.accept_prob.push(st.accept_prob);
            cursors[k].stats.num_leapfrog.push(st.num_leapfrog);
            cursors[k].stats.potential.push(st.potential);
            cursors[k].stats.diverging.push(st.diverging);
            cursors[k].stats.depth.push(st.depth);

            if i < opts.num_warmup {
                if opts.fixed_step_size.is_none() {
                    if !st.poisoned {
                        das[k].update(st.accept_prob);
                    }
                    steps[k] = das[k].step_size();
                }
                if opts.adapt_mass && schedule.in_slow(i) {
                    if !st.poisoned {
                        ws.proposal_lane(k, &mut zrow);
                        welfords[k].update(&zrow);
                    }
                    if closes.contains(&i) {
                        let v = welfords[k].regularized_variance();
                        for (d, vd) in v.iter().enumerate() {
                            inv_mass[d * l + k] = *vd;
                        }
                        welfords[k].reset();
                        if opts.fixed_step_size.is_none() {
                            das[k].restart(das[k].step_size());
                            steps[k] = das[k].step_size();
                        }
                    }
                }
                if i + 1 == opts.num_warmup && opts.fixed_step_size.is_none() {
                    steps[k] = das[k].final_step_size();
                }
            } else {
                ws.proposal_lane(k, &mut zrow);
                cursors[k].samples.extend_from_slice(&zrow);
                cursors[k].sample_leapfrogs += st.num_leapfrog as u64;
            }
            cursors[k].i = i + 1;
        }
        // lane 0's step size stands in for the lock-step trajectory —
        // recorded after all adaptation decisions for this draw
        if let Some(&s) = steps.first() {
            rec.record_step_size(s);
        }
        if i + 1 == opts.num_warmup {
            warmup_secs = t_warm.elapsed().as_secs_f64();
            rec.set_phase(Phase::Sampling);
        }
        if checkpoint_every > 0 && (i + 1) % checkpoint_every == 0 && i + 1 < total {
            sync_cursors(cursors, &rngs, &das, &steps, &welfords, &z, &inv_mass, dim);
            sink(cursors)?;
        }
    }
    if opts.num_warmup == 0 {
        warmup_secs = 0.0;
    }
    let sample_secs = t_warm.elapsed().as_secs_f64() - warmup_secs;
    rec.add_span_secs(SpanKind::Warmup, warmup_secs);
    rec.add_span_secs(SpanKind::Sampling, sample_secs);

    sync_cursors(cursors, &rngs, &das, &steps, &welfords, &z, &inv_mass, dim);
    Ok((warmup_secs, sample_secs, completed))
}

/// Compile an effect-handler program and run `num_chains` NUTS chains
/// with the chosen execution strategy — the one entry point behind the
/// `fugue sample-model --chain-method` CLI.  All three methods produce
/// bitwise-identical per-chain results for the same options.
///
/// `Vectorized` evaluates the model through the batched compiler
/// ([`BatchedCompiledModel`]), which supports every `ProbCtx` operation
/// **except** reading primal values via `ProbCtx::val` with more than
/// one lane (a multi-lane node has one primal per chain; the batch
/// tape panics with a descriptive message rather than silently using
/// lane 0).  All zoo models qualify.  A `val`-reading model can still
/// run lock-step by composing the pieces directly: compile one scalar
/// [`crate::compile::CompiledModel`] per chain and pass
/// `ScalarLanes::new(pots)` to [`run_chains_vectorized`]
/// (see [`crate::mcmc::ScalarLanes`]).
pub fn run_compiled_chains_method<M: EffModel + Clone + Send + Sync>(
    model: &M,
    method: ChainMethod,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
) -> Result<(SiteLayout, Vec<ChainResult>)> {
    run_compiled_chains_method_opt(model, method, num_chains, max_tree_depth, opts, true)
}

/// [`run_compiled_chains_method`] with an explicit optimizing-compiler
/// switch: `optimized = false` serves every frozen evaluation (scalar,
/// batched, and tiled alike) from the tape interpreter instead of the
/// fused/re-slotted execution plan.  The two settings are bitwise
/// identical across all three chain methods
/// (`rust/tests/tape_opt.rs`); the switch exists for benchmarking and
/// cross-checks.
pub fn run_compiled_chains_method_opt<M: EffModel + Clone + Send + Sync>(
    model: &M,
    method: ChainMethod,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
    optimized: bool,
) -> Result<(SiteLayout, Vec<ChainResult>)> {
    match method {
        ChainMethod::Parallel => {
            run_compiled_chains_opt(model, num_chains, max_tree_depth, opts, optimized)
        }
        ChainMethod::Sequential => {
            let layout = SiteLayout::trace(model, opts.seed)?;
            let mut pot = CompiledModel::new(model.clone(), layout.clone());
            pot.set_optimized(optimized);
            let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, max_tree_depth);
            let results = run_chains(&mut sampler, num_chains, opts)?;
            Ok((layout, results))
        }
        ChainMethod::Vectorized => {
            let layout = SiteLayout::trace(model, opts.seed)?;
            if num_chains == 0 {
                return Ok((layout, Vec::new()));
            }
            if num_chains > TILED_LANE_THRESHOLD {
                // lane-sharded regime: tile the lanes across worker
                // threads; every lane stays bitwise-identical to the
                // single-program engine below (rust/tests/lane_scaling.rs)
                let threads = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1);
                let tile = auto_tile_width(num_chains, threads);
                let mut pot = tiled_from_layout(model, &layout, num_chains, tile);
                pot.set_optimized(optimized);
                let results = run_chains_vectorized(&mut pot, opts, max_tree_depth)?;
                return Ok((layout, results));
            }
            let mut pot = BatchedCompiledModel::new(model.clone(), layout.clone(), num_chains);
            pot.set_optimized(optimized);
            let results = run_chains_vectorized(&mut pot, opts, max_tree_depth)?;
            Ok((layout, results))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::{Potential, ScalarLanes};

    #[derive(Clone)]
    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    fn opts() -> NutsOptions {
        NutsOptions {
            num_warmup: 120,
            num_samples: 150,
            seed: 99,
            ..Default::default()
        }
    }

    /// The full vectorized runner — warmup adaptation included — must
    /// reproduce the sequential chains bitwise, lane for lane.
    #[test]
    fn vectorized_matches_sequential_bitwise() {
        let mut pot = ScalarLanes::new(vec![Gauss; 4]);
        let vec_res = run_chains_vectorized(&mut pot, &opts(), 10).unwrap();

        let mut sampler = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let seq_res = run_chains(&mut sampler, 4, &opts()).unwrap();

        assert_eq!(vec_res.len(), seq_res.len());
        for (v, s) in vec_res.iter().zip(&seq_res) {
            assert_eq!(v.samples, s.samples);
            assert_eq!(v.step_size, s.step_size);
            assert_eq!(v.inv_mass, s.inv_mass);
            assert_eq!(v.divergences, s.divergences);
            assert_eq!(v.stats.accept_prob, s.stats.accept_prob);
            assert_eq!(v.stats.num_leapfrog, s.stats.num_leapfrog);
            assert_eq!(v.total_leapfrogs, s.total_leapfrogs);
        }
    }

    /// Fixed step size disables adaptation in both engines identically.
    #[test]
    fn vectorized_fixed_step_matches_sequential() {
        let o = NutsOptions {
            num_warmup: 40,
            num_samples: 60,
            fixed_step_size: Some(0.25),
            adapt_mass: false,
            seed: 5,
            ..Default::default()
        };
        let mut pot = ScalarLanes::new(vec![Gauss; 3]);
        let vec_res = run_chains_vectorized(&mut pot, &o, 8).unwrap();
        let mut sampler = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 8);
        let seq_res = run_chains(&mut sampler, 3, &o).unwrap();
        for (v, s) in vec_res.iter().zip(&seq_res) {
            assert_eq!(v.samples, s.samples);
            assert_eq!(v.step_size, s.step_size);
        }
    }

    #[test]
    fn chain_method_parses() {
        assert_eq!(
            ChainMethod::parse("sequential").unwrap(),
            ChainMethod::Sequential
        );
        assert_eq!(ChainMethod::parse("parallel").unwrap(), ChainMethod::Parallel);
        assert_eq!(
            ChainMethod::parse("vectorized").unwrap(),
            ChainMethod::Vectorized
        );
        assert!(ChainMethod::parse("warp").is_err());
        assert_eq!(ChainMethod::Vectorized.name(), "vectorized");
    }
}
