//! The vectorized chain engine: K chains sampled **in lock-step**
//! through the batched NUTS kernel ([`crate::mcmc::batch_nuts`]), with
//! one fused [`BatchPotential`] gradient evaluation shared by every
//! chain per leapfrog — the native reproduction of NumPyro's
//! `chain_method="vectorized"` (`vmap` over the sampler, paper E7).
//!
//! Each lane keeps its **own** warmup state — dual-averaged step size,
//! Welford mass-matrix window, RNG stream — updated by exactly the
//! same schedule as the sequential [`crate::coordinator::run_chain`]
//! loop, and every lane derives its seed/init from the shared
//! [`chain_start`].  Chain `k` of
//! a vectorized run is therefore **bitwise identical** to chain `k` of
//! a sequential or thread-parallel run with the same options (pinned by
//! this module's tests and `rust/tests/chain_methods.rs`): the three
//! [`ChainMethod`]s are pure execution strategies, invisible to the
//! model and to the statistics.
//!
//! The lane trade-off: per draw, every chain waits for the longest
//! lane's trajectory (masked lanes still occupy SIMD width), but each
//! leapfrog costs one batched evaluation instead of K scalar ones.
//! `fugue bench` quantifies the exchange as
//! `vectorized_speedup_vs_parallel` / `vectorized_speedup_vs_sequential`
//! per chain count in `BENCH_native.json`.

use anyhow::{bail, Result};

use crate::compile::{BatchedCompiledModel, CompiledModel, EffModel, SiteLayout};
use crate::coordinator::chain::{chain_start, run_chains, ChainResult, ChainStats, NutsOptions};
use crate::coordinator::parallel::run_compiled_chains;
use crate::coordinator::sampler::{NativeSampler, TreeAlgorithm};
use crate::coordinator::warmup::WarmupSchedule;
use crate::mcmc::batch_nuts::{draw_batch, BatchTreeWorkspace};
use crate::mcmc::{BatchPotential, DrawStats, DualAverage, Welford};
use crate::rng::Rng;

/// Multi-chain execution strategy (NumPyro's `chain_method`):
/// same statistics, different schedulers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChainMethod {
    /// One chain after another on the calling thread.
    Sequential,
    /// One OS thread per chain ([`crate::coordinator::ParallelChainRunner`]).
    Parallel,
    /// All chains in lock-step through the batched NUTS kernel with a
    /// fused multi-lane potential ([`run_chains_vectorized`]).
    Vectorized,
}

impl ChainMethod {
    pub fn parse(s: &str) -> Result<ChainMethod> {
        Ok(match s {
            "sequential" => ChainMethod::Sequential,
            "parallel" => ChainMethod::Parallel,
            "vectorized" => ChainMethod::Vectorized,
            other => bail!("unknown chain method '{other}' (sequential|parallel|vectorized)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChainMethod::Sequential => "sequential",
            ChainMethod::Parallel => "parallel",
            ChainMethod::Vectorized => "vectorized",
        }
    }
}

/// Run `pot.lanes()` chains in lock-step through the batched NUTS
/// kernel: Stan-style warmup (per-lane dual averaging + Welford
/// windows) then sampling, mirroring the sequential [`run_chain`]
/// bookkeeping statement-for-statement per lane.
///
/// Returns one [`ChainResult`] per lane, in chain order.  The per-phase
/// wall-clock fields (`warmup_secs` / `sample_secs`) are shared across
/// lanes — the lanes advance together, so per-chain timing is the
/// engine timing.
///
/// [`run_chain`]: crate::coordinator::run_chain
pub fn run_chains_vectorized<BP: BatchPotential + ?Sized>(
    pot: &mut BP,
    opts: &NutsOptions,
    max_tree_depth: u32,
) -> Result<Vec<ChainResult>> {
    let dim = pot.dim();
    let l = pot.lanes();
    if l == 0 {
        return Ok(Vec::new());
    }
    let schedule = WarmupSchedule::build(opts.num_warmup);
    let closes = schedule.window_closes();

    // per-lane seeds/inits from the shared derivation — chain k here
    // IS chain k of run_chains / ParallelChainRunner
    let mut rngs: Vec<Rng> = Vec::with_capacity(l);
    let mut z = vec![0.0; dim * l];
    for k in 0..l {
        let (init_z, chain_opts) = chain_start(dim, opts, k);
        rngs.push(Rng::new(chain_opts.seed));
        for i in 0..dim {
            z[i * l + k] = init_z[i];
        }
    }

    let init_step = opts.fixed_step_size.unwrap_or(opts.init_step_size);
    let mut das: Vec<DualAverage> = (0..l)
        .map(|_| DualAverage::new(init_step, opts.target_accept))
        .collect();
    let mut steps = vec![init_step; l];
    let mut welfords: Vec<Welford> = (0..l).map(|_| Welford::new(dim)).collect();
    let mut inv_mass = vec![1.0; dim * l];

    let total = opts.num_warmup + opts.num_samples;
    let mut stats: Vec<ChainStats> = (0..l).map(|_| ChainStats::default()).collect();
    for s in &mut stats {
        s.accept_prob.reserve(total);
        s.num_leapfrog.reserve(total);
        s.potential.reserve(total);
        s.diverging.reserve(total);
        s.depth.reserve(total);
    }
    let mut samples: Vec<Vec<f64>> = (0..l)
        .map(|_| Vec::with_capacity(opts.num_samples * dim))
        .collect();
    let mut sample_leapfrogs = vec![0u64; l];
    let mut total_leapfrogs = vec![0u64; l];
    let mut divergences = vec![0u64; l];

    let mut ws = BatchTreeWorkspace::new(dim, l, max_tree_depth);
    let mut draw_stats = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
        };
        l
    ];
    let mut zrow = vec![0.0; dim];

    let t_warm = std::time::Instant::now();
    let mut warmup_secs = 0.0;

    for i in 0..total {
        draw_batch(
            pot,
            &mut rngs,
            &mut ws,
            &z,
            &steps,
            &inv_mass,
            max_tree_depth,
            &mut draw_stats,
        );
        z.copy_from_slice(ws.proposal());
        for k in 0..l {
            let st = draw_stats[k];
            total_leapfrogs[k] += st.num_leapfrog as u64;
            if st.diverging {
                divergences[k] += 1;
            }
            stats[k].accept_prob.push(st.accept_prob);
            stats[k].num_leapfrog.push(st.num_leapfrog);
            stats[k].potential.push(st.potential);
            stats[k].diverging.push(st.diverging);
            stats[k].depth.push(st.depth);

            if i < opts.num_warmup {
                if opts.fixed_step_size.is_none() {
                    das[k].update(st.accept_prob);
                    steps[k] = das[k].step_size();
                }
                if opts.adapt_mass && schedule.in_slow(i) {
                    ws.proposal_lane(k, &mut zrow);
                    welfords[k].update(&zrow);
                    if closes.contains(&i) {
                        let v = welfords[k].regularized_variance();
                        for (d, vd) in v.iter().enumerate() {
                            inv_mass[d * l + k] = *vd;
                        }
                        welfords[k].reset();
                        if opts.fixed_step_size.is_none() {
                            das[k].restart(das[k].step_size());
                            steps[k] = das[k].step_size();
                        }
                    }
                }
                if i + 1 == opts.num_warmup && opts.fixed_step_size.is_none() {
                    steps[k] = das[k].final_step_size();
                }
            } else {
                ws.proposal_lane(k, &mut zrow);
                samples[k].extend_from_slice(&zrow);
                sample_leapfrogs[k] += st.num_leapfrog as u64;
            }
        }
        if i + 1 == opts.num_warmup {
            warmup_secs = t_warm.elapsed().as_secs_f64();
        }
    }
    if opts.num_warmup == 0 {
        warmup_secs = 0.0;
    }
    let sample_secs = t_warm.elapsed().as_secs_f64() - warmup_secs;

    let mut results = Vec::with_capacity(l);
    for k in 0..l {
        let mut im = vec![0.0; dim];
        for (i, m) in im.iter_mut().enumerate() {
            *m = inv_mass[i * l + k];
        }
        results.push(ChainResult {
            samples: std::mem::take(&mut samples[k]),
            dim,
            stats: std::mem::take(&mut stats[k]),
            step_size: steps[k],
            inv_mass: im,
            warmup_secs,
            sample_secs,
            sample_leapfrogs: sample_leapfrogs[k],
            total_leapfrogs: total_leapfrogs[k],
            divergences: divergences[k],
        });
    }
    Ok(results)
}

/// Compile an effect-handler program and run `num_chains` NUTS chains
/// with the chosen execution strategy — the one entry point behind the
/// `fugue sample-model --chain-method` CLI.  All three methods produce
/// bitwise-identical per-chain results for the same options.
///
/// `Vectorized` evaluates the model through the batched compiler
/// ([`BatchedCompiledModel`]), which supports every `ProbCtx` operation
/// **except** reading primal values via `ProbCtx::val` with more than
/// one lane (a multi-lane node has one primal per chain; the batch
/// tape panics with a descriptive message rather than silently using
/// lane 0).  All zoo models qualify.  A `val`-reading model can still
/// run lock-step by composing the pieces directly: compile one scalar
/// [`crate::compile::CompiledModel`] per chain and pass
/// `ScalarLanes::new(pots)` to [`run_chains_vectorized`]
/// (see [`crate::mcmc::ScalarLanes`]).
pub fn run_compiled_chains_method<M: EffModel + Clone + Sync>(
    model: &M,
    method: ChainMethod,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
) -> Result<(SiteLayout, Vec<ChainResult>)> {
    match method {
        ChainMethod::Parallel => run_compiled_chains(model, num_chains, max_tree_depth, opts),
        ChainMethod::Sequential => {
            let layout = SiteLayout::trace(model, opts.seed)?;
            let mut sampler = NativeSampler::new(
                CompiledModel::new(model.clone(), layout.clone()),
                TreeAlgorithm::Iterative,
                max_tree_depth,
            );
            let results = run_chains(&mut sampler, num_chains, opts)?;
            Ok((layout, results))
        }
        ChainMethod::Vectorized => {
            let layout = SiteLayout::trace(model, opts.seed)?;
            if num_chains == 0 {
                return Ok((layout, Vec::new()));
            }
            let mut pot = BatchedCompiledModel::new(model.clone(), layout.clone(), num_chains);
            let results = run_chains_vectorized(&mut pot, opts, max_tree_depth)?;
            Ok((layout, results))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcmc::{Potential, ScalarLanes};

    #[derive(Clone)]
    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    fn opts() -> NutsOptions {
        NutsOptions {
            num_warmup: 120,
            num_samples: 150,
            seed: 99,
            ..Default::default()
        }
    }

    /// The full vectorized runner — warmup adaptation included — must
    /// reproduce the sequential chains bitwise, lane for lane.
    #[test]
    fn vectorized_matches_sequential_bitwise() {
        let mut pot = ScalarLanes::new(vec![Gauss; 4]);
        let vec_res = run_chains_vectorized(&mut pot, &opts(), 10).unwrap();

        let mut sampler = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let seq_res = run_chains(&mut sampler, 4, &opts()).unwrap();

        assert_eq!(vec_res.len(), seq_res.len());
        for (v, s) in vec_res.iter().zip(&seq_res) {
            assert_eq!(v.samples, s.samples);
            assert_eq!(v.step_size, s.step_size);
            assert_eq!(v.inv_mass, s.inv_mass);
            assert_eq!(v.divergences, s.divergences);
            assert_eq!(v.stats.accept_prob, s.stats.accept_prob);
            assert_eq!(v.stats.num_leapfrog, s.stats.num_leapfrog);
            assert_eq!(v.total_leapfrogs, s.total_leapfrogs);
        }
    }

    /// Fixed step size disables adaptation in both engines identically.
    #[test]
    fn vectorized_fixed_step_matches_sequential() {
        let o = NutsOptions {
            num_warmup: 40,
            num_samples: 60,
            fixed_step_size: Some(0.25),
            adapt_mass: false,
            seed: 5,
            ..Default::default()
        };
        let mut pot = ScalarLanes::new(vec![Gauss; 3]);
        let vec_res = run_chains_vectorized(&mut pot, &o, 8).unwrap();
        let mut sampler = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 8);
        let seq_res = run_chains(&mut sampler, 3, &o).unwrap();
        for (v, s) in vec_res.iter().zip(&seq_res) {
            assert_eq!(v.samples, s.samples);
            assert_eq!(v.step_size, s.step_size);
        }
    }

    #[test]
    fn chain_method_parses() {
        assert_eq!(
            ChainMethod::parse("sequential").unwrap(),
            ChainMethod::Sequential
        );
        assert_eq!(ChainMethod::parse("parallel").unwrap(), ChainMethod::Parallel);
        assert_eq!(
            ChainMethod::parse("vectorized").unwrap(),
            ChainMethod::Vectorized
        );
        assert!(ChainMethod::parse("warp").is_err());
        assert_eq!(ChainMethod::Vectorized.name(), "vectorized");
    }
}
