//! Checkpoint/resume for both inference engines — the persistence half
//! of the fault-containment layer.
//!
//! A checkpoint is a **draw-boundary snapshot**: the complete resumable
//! state of every chain ([`ChainCursor`]) or of an SVI fit
//! ([`SviCursor`]) at the moment all per-draw scratch is dead.  Because
//! the runners below replay the exact statement order of the
//! uninterrupted loops ([`advance_chain`] /
//! [`run_chains_vectorized_from`] / `NativeSvi::run_with`), a run that
//! is killed, reloaded and resumed produces **bitwise-identical**
//! samples, statistics and adapted tuning to one that never stopped —
//! pinned by this module's tests and `rust/tests/chaos.rs`.
//!
//! ## Format
//!
//! The file is JSON (the crate's own [`crate::util::json`] — no serde
//! in the offline dependency set) with one deliberate twist: every
//! `f64` and every `u64` is stored as its 16-hex-digit bit pattern
//! (`f64::to_bits` / the raw integer), e.g. `"3fe0000000000000"` for
//! `0.5`.  Decimal round-tripping through a `f64`-backed parser cannot
//! represent NaN/±Inf and risks last-ulp drift — bit patterns make the
//! resume contract exact by construction.  Counters small enough to be
//! exact in a double (`i`, lengths, `num_leapfrog`, `depth`) stay plain
//! JSON numbers for readability.
//!
//! Writes are atomic (temp file + rename), so a kill mid-write leaves
//! the previous checkpoint intact, never a torn file.
//!
//! ## Budgets
//!
//! Every runner takes an optional wall-clock deadline
//! ([`CheckpointConfig::max_seconds`]).  Crossing it is not an error:
//! the run stops at the next draw/step boundary, saves a final
//! checkpoint, and returns partial results with `completed = false`
//! (the CLI surfaces [`crate::error::InferenceError::BudgetExhausted`]
//! as a warning, not a failure).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::compile::{BatchedCompiledModel, CompiledModel, EffModel, SiteLayout, SubsampledModel};
use crate::coordinator::chain::{
    advance_chain, chain_start, ChainCursor, ChainResult, ChainStats, NutsOptions,
};
use crate::coordinator::sampler::{NativeSampler, Sampler, TreeAlgorithm};
use crate::coordinator::vectorized::{run_chains_vectorized_from, ChainMethod};
use crate::coordinator::warmup::WarmupSchedule;
use crate::error::InferenceError;
use crate::data::stream::MinibatchScheduler;
use crate::mcmc::{DualAverage, Welford};
use crate::obs::{Counter, Recorder, SpanKind};
use crate::rng::Rng;
use crate::svi::native::{
    BatchedParticles, NativeSvi, NativeSviResult, ScalarParticles, SviCursor, SviOptions,
};
use crate::svi::subsample::{
    scheduler_rng, SubsampledBatchedParticles, SubsampledScalarParticles,
};
use crate::util::json::Json;

/// How a checkpointed run persists and budgets itself.
#[derive(Debug, Clone, Default)]
pub struct CheckpointConfig {
    /// Checkpoint file (`--checkpoint`).  `None` disables persistence
    /// (budgets still work — the partial results are just not
    /// resumable).
    pub path: Option<PathBuf>,
    /// Load `path` and continue from it (`--resume`).  Ignored when the
    /// file does not exist yet, so `--resume` is safe on the first run.
    pub resume: bool,
    /// Save every N draws/steps (`--checkpoint-every`, 0 = only the
    /// final snapshot).
    pub every: usize,
    /// Wall-clock budget for this invocation (`--max-seconds`).
    pub max_seconds: Option<f64>,
}

impl CheckpointConfig {
    pub fn deadline(&self) -> Option<Instant> {
        self.max_seconds
            .map(|s| Instant::now() + Duration::from_secs_f64(s.max(0.0)))
    }
}

// ---------------------------------------------------------------------
// encoding helpers: exact bit-pattern JSON
// ---------------------------------------------------------------------

fn enc_f64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn enc_u64(x: u64) -> Json {
    Json::Str(format!("{:016x}", x))
}

fn enc_f64s(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| enc_f64(x)).collect())
}

fn enc_u32s(xs: &[u32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn enc_bools(xs: &[bool]) -> Json {
    Json::Arr(xs.iter().map(|&b| Json::Bool(b)).collect())
}

fn ck_err(path: &Path, msg: String) -> anyhow::Error {
    InferenceError::Checkpoint {
        path: path.display().to_string(),
        msg,
    }
    .into()
}

fn dec_u64(j: &Json) -> Option<u64> {
    let s = j.as_str()?;
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

fn dec_f64(j: &Json) -> Option<f64> {
    dec_u64(j).map(f64::from_bits)
}

fn dec_f64s(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(dec_f64).collect()
}

fn dec_u32s(j: &Json) -> Option<Vec<u32>> {
    j.as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|n| n as u32))
        .collect()
}

fn dec_bools(j: &Json) -> Option<Vec<bool>> {
    j.as_arr()?.iter().map(|v| v.as_bool()).collect()
}

/// Fetch + decode one field of a checkpoint object, with the field name
/// in the error.
fn field<T>(
    obj: &Json,
    key: &str,
    path: &Path,
    dec: impl Fn(&Json) -> Option<T>,
) -> Result<T> {
    obj.get(key)
        .and_then(dec)
        .ok_or_else(|| ck_err(path, format!("missing or malformed field '{key}'")))
}

fn write_atomic(path: &Path, text: &str) -> Result<()> {
    // flight recorder: checkpoint I/O is a wall-clock span + write
    // counter — observation only, the bytes written are untouched
    let rec = Recorder::global();
    let _io_span = rec.span(SpanKind::CheckpointIo);
    rec.incr(Counter::CheckpointWrites);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, text).map_err(|e| ck_err(&tmp, format!("write failed: {e}")))?;
    std::fs::rename(&tmp, path).map_err(|e| ck_err(path, format!("rename failed: {e}")))?;
    Ok(())
}

fn load_root(path: &Path, format: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ck_err(path, format!("read failed: {e}")))?;
    let root = Json::parse(&text).map_err(|e| ck_err(path, format!("parse failed: {e}")))?;
    let got = root.get("format").and_then(|f| f.as_str()).unwrap_or("?");
    if got != format {
        return Err(ck_err(path, format!("format is '{got}', expected '{format}'")));
    }
    let version = root.get("version").and_then(|v| v.as_i64()).unwrap_or(-1);
    if version != 1 {
        return Err(ck_err(path, format!("unsupported version {version}")));
    }
    Ok(root)
}

/// Validate one header field against the resuming run's configuration.
fn check_cfg(path: &Path, key: &str, expected: u64, got: Option<u64>) -> Result<()> {
    match got {
        Some(g) if g == expected => Ok(()),
        other => Err(InferenceError::LayoutViolation {
            expected: format!("{key}={expected}"),
            got: format!("{key}={other:?}"),
            context: format!("checkpoint {}", path.display()),
        }
        .into()),
    }
}

// ---------------------------------------------------------------------
// chain checkpoints
// ---------------------------------------------------------------------

fn cursor_to_json(cur: &ChainCursor) -> Json {
    let (rng_s, rng_spare) = cur.rng.state();
    let (ls, lsa, gs, t, mu, target) = cur.da.state();
    let mut o = std::collections::BTreeMap::new();
    o.insert("i".into(), Json::Num(cur.i as f64));
    o.insert("z".into(), enc_f64s(&cur.z));
    o.insert(
        "rng_s".into(),
        Json::Arr(rng_s.iter().map(|&w| enc_u64(w)).collect()),
    );
    o.insert(
        "rng_spare".into(),
        rng_spare.map_or(Json::Null, enc_f64),
    );
    o.insert("da".into(), enc_f64s(&[ls, lsa, gs, t, mu, target]));
    o.insert("wf_mean".into(), enc_f64s(&cur.welford.mean));
    o.insert("wf_m2".into(), enc_f64s(cur.welford.m2()));
    o.insert("wf_count".into(), enc_u64(cur.welford.count));
    o.insert("step_size".into(), enc_f64(cur.step_size));
    o.insert("inv_mass".into(), enc_f64s(&cur.inv_mass));
    o.insert("accept_prob".into(), enc_f64s(&cur.stats.accept_prob));
    o.insert("num_leapfrog".into(), enc_u32s(&cur.stats.num_leapfrog));
    o.insert("potential".into(), enc_f64s(&cur.stats.potential));
    o.insert("diverging".into(), enc_bools(&cur.stats.diverging));
    o.insert("depth".into(), enc_u32s(&cur.stats.depth));
    o.insert("samples".into(), enc_f64s(&cur.samples));
    o.insert("sample_leapfrogs".into(), enc_u64(cur.sample_leapfrogs));
    o.insert("total_leapfrogs".into(), enc_u64(cur.total_leapfrogs));
    o.insert("divergences".into(), enc_u64(cur.divergences));
    o.insert("quarantines".into(), enc_u64(cur.quarantines));
    Json::Obj(o)
}

fn cursor_from_json(j: &Json, path: &Path, dim: usize) -> Result<ChainCursor> {
    let i = field(j, "i", path, |v| v.as_usize())?;
    let z = field(j, "z", path, dec_f64s)?;
    if z.len() != dim {
        return Err(InferenceError::LayoutViolation {
            expected: format!("dim={dim}"),
            got: format!("dim={}", z.len()),
            context: format!("checkpoint {}", path.display()),
        }
        .into());
    }
    let rng_s_v = field(j, "rng_s", path, |v| {
        v.as_arr()?.iter().map(dec_u64).collect::<Option<Vec<u64>>>()
    })?;
    if rng_s_v.len() != 4 {
        return Err(ck_err(path, "rng_s must have 4 words".into()));
    }
    let rng_spare = match j.get("rng_spare") {
        Some(Json::Null) | None => None,
        Some(v) => Some(
            dec_f64(v).ok_or_else(|| ck_err(path, "malformed field 'rng_spare'".into()))?,
        ),
    };
    let da_v = field(j, "da", path, dec_f64s)?;
    if da_v.len() != 6 {
        return Err(ck_err(path, "da must have 6 entries".into()));
    }
    let wf_mean = field(j, "wf_mean", path, dec_f64s)?;
    let wf_m2 = field(j, "wf_m2", path, dec_f64s)?;
    if wf_mean.len() != dim || wf_m2.len() != dim {
        return Err(ck_err(path, "welford buffers have wrong length".into()));
    }
    let stats = ChainStats {
        accept_prob: field(j, "accept_prob", path, dec_f64s)?,
        num_leapfrog: field(j, "num_leapfrog", path, dec_u32s)?,
        potential: field(j, "potential", path, dec_f64s)?,
        diverging: field(j, "diverging", path, dec_bools)?,
        depth: field(j, "depth", path, dec_u32s)?,
    };
    if stats.accept_prob.len() != i
        || stats.num_leapfrog.len() != i
        || stats.potential.len() != i
        || stats.diverging.len() != i
        || stats.depth.len() != i
    {
        return Err(ck_err(path, format!("stats length disagrees with draw index {i}")));
    }
    Ok(ChainCursor {
        i,
        z,
        rng: Rng::from_state([rng_s_v[0], rng_s_v[1], rng_s_v[2], rng_s_v[3]], rng_spare),
        da: DualAverage::from_state(da_v[0], da_v[1], da_v[2], da_v[3], da_v[4], da_v[5]),
        welford: Welford::from_state(wf_mean, wf_m2, field(j, "wf_count", path, dec_u64)?),
        step_size: field(j, "step_size", path, dec_f64)?,
        inv_mass: field(j, "inv_mass", path, dec_f64s)?,
        stats,
        samples: field(j, "samples", path, dec_f64s)?,
        sample_leapfrogs: field(j, "sample_leapfrogs", path, dec_u64)?,
        total_leapfrogs: field(j, "total_leapfrogs", path, dec_u64)?,
        divergences: field(j, "divergences", path, dec_u64)?,
        quarantines: field(j, "quarantines", path, dec_u64)?,
    })
}

/// Serialize every chain's draw-boundary state atomically.
pub fn save_chain_checkpoint(
    path: &Path,
    opts: &NutsOptions,
    dim: usize,
    cursors: &[ChainCursor],
) -> Result<()> {
    let mut o = std::collections::BTreeMap::new();
    o.insert("format".into(), Json::Str("fugue-chain-checkpoint".into()));
    o.insert("version".into(), Json::Num(1.0));
    o.insert("dim".into(), Json::Num(dim as f64));
    o.insert("num_warmup".into(), Json::Num(opts.num_warmup as f64));
    o.insert("num_samples".into(), Json::Num(opts.num_samples as f64));
    o.insert("seed".into(), enc_u64(opts.seed));
    o.insert("num_chains".into(), Json::Num(cursors.len() as f64));
    o.insert(
        "cursors".into(),
        Json::Arr(cursors.iter().map(cursor_to_json).collect()),
    );
    write_atomic(path, &Json::Obj(o).to_string_pretty())
}

/// Load a chain checkpoint and validate it against the resuming run's
/// configuration (dimension, draw counts, seed, chain count must all
/// match — resuming under different options would silently break the
/// bitwise contract, so it is refused).
pub fn load_chain_checkpoint(
    path: &Path,
    opts: &NutsOptions,
    num_chains: usize,
    dim: usize,
) -> Result<Vec<ChainCursor>> {
    let root = load_root(path, "fugue-chain-checkpoint")?;
    check_cfg(path, "dim", dim as u64, root.get("dim").and_then(|v| v.as_f64()).map(|n| n as u64))?;
    check_cfg(
        path,
        "num_warmup",
        opts.num_warmup as u64,
        root.get("num_warmup").and_then(|v| v.as_f64()).map(|n| n as u64),
    )?;
    check_cfg(
        path,
        "num_samples",
        opts.num_samples as u64,
        root.get("num_samples").and_then(|v| v.as_f64()).map(|n| n as u64),
    )?;
    check_cfg(path, "seed", opts.seed, root.get("seed").and_then(dec_u64))?;
    check_cfg(
        path,
        "num_chains",
        num_chains as u64,
        root.get("num_chains").and_then(|v| v.as_f64()).map(|n| n as u64),
    )?;
    let arr = root
        .get("cursors")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| ck_err(path, "missing 'cursors' array".into()))?;
    if arr.len() != num_chains {
        return Err(ck_err(path, format!("{} cursors for {num_chains} chains", arr.len())));
    }
    arr.iter().map(|c| cursor_from_json(c, path, dim)).collect()
}

/// Sequential chains with checkpoint/resume and a wall-clock budget:
/// the containment-aware twin of [`crate::coordinator::run_chains`],
/// bitwise-identical to it (and to an interrupted + resumed invocation
/// of itself) draw for draw.  Returns `(results, completed)`;
/// `completed = false` means the budget cut the run short and the
/// results are partial (resumable from the saved checkpoint).
pub fn run_chains_checkpointed<S: Sampler>(
    sampler: &mut S,
    num_chains: usize,
    opts: &NutsOptions,
    cfg: &CheckpointConfig,
) -> Result<(Vec<ChainResult>, bool)> {
    let dim = sampler.dim();
    let total = opts.num_warmup + opts.num_samples;
    let schedule = WarmupSchedule::build(opts.num_warmup);
    let closes = schedule.window_closes();
    let starts: Vec<(Vec<f64>, NutsOptions)> =
        (0..num_chains).map(|c| chain_start(dim, opts, c)).collect();

    let mut cursors: Vec<ChainCursor> = match &cfg.path {
        Some(p) if cfg.resume && p.exists() => load_chain_checkpoint(p, opts, num_chains, dim)?,
        _ => starts.iter().map(|(z, o)| ChainCursor::new(z, o)).collect(),
    };

    let deadline = cfg.deadline();
    let mut completed = true;
    let mut timings = vec![(0.0, 0.0); num_chains];
    let mut since_save = 0usize;
    for c in 0..num_chains {
        if !completed {
            break;
        }
        let t0 = Instant::now();
        let mut warm = 0.0;
        while cursors[c].i < total {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    completed = false;
                    break;
                }
            }
            advance_chain(sampler, &mut cursors[c], &starts[c].1, &schedule, &closes)?;
            if cursors[c].i == opts.num_warmup {
                warm = t0.elapsed().as_secs_f64();
            }
            since_save += 1;
            if cfg.every > 0 && since_save % cfg.every == 0 {
                if let Some(p) = &cfg.path {
                    save_chain_checkpoint(p, opts, dim, &cursors)?;
                }
            }
        }
        timings[c] = (warm, t0.elapsed().as_secs_f64() - warm);
    }
    if let Some(p) = &cfg.path {
        save_chain_checkpoint(p, opts, dim, &cursors)?;
    }
    let results = cursors
        .into_iter()
        .zip(timings)
        .map(|(cur, (w, s))| cur.into_result(w, s))
        .collect();
    Ok((results, completed))
}

/// Compile an effect-handler program and run checkpointed/budgeted
/// chains with the chosen execution strategy — the fault-contained
/// twin of [`crate::coordinator::run_compiled_chains_method`].
///
/// `Sequential` and `Parallel` both run the sequential checkpointed
/// loop: a global draw-boundary snapshot wants one thread of control,
/// and the three methods are bitwise-identical anyway, so nothing in
/// the output changes.  `Vectorized` drives the lock-step engine
/// through its native checkpoint sink.  A checkpoint written by the
/// vectorized engine (all chains parked at one draw index) resumes
/// under any method; a mid-chain sequential checkpoint resumes
/// sequentially only — the vectorized path refuses it with a
/// descriptive [`InferenceError::Checkpoint`].
pub fn run_compiled_chains_checkpointed<M: EffModel + Clone + Send + Sync>(
    model: &M,
    method: ChainMethod,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
    cfg: &CheckpointConfig,
) -> Result<(SiteLayout, Vec<ChainResult>, bool)> {
    let layout = SiteLayout::trace(model, opts.seed)?;
    if num_chains == 0 {
        return Ok((layout, Vec::new(), true));
    }
    match method {
        ChainMethod::Sequential | ChainMethod::Parallel => {
            let mut sampler = NativeSampler::new(
                CompiledModel::new(model.clone(), layout.clone()),
                TreeAlgorithm::Iterative,
                max_tree_depth,
            );
            let (results, completed) =
                run_chains_checkpointed(&mut sampler, num_chains, opts, cfg)?;
            Ok((layout, results, completed))
        }
        ChainMethod::Vectorized => {
            let dim = layout.dim;
            let mut cursors: Vec<ChainCursor> = match &cfg.path {
                Some(p) if cfg.resume && p.exists() => {
                    let cs = load_chain_checkpoint(p, opts, num_chains, dim)?;
                    if cs.iter().any(|c| c.i != cs[0].i) {
                        return Err(ck_err(
                            p,
                            "not a lock-step snapshot (chains at different draw \
                             indices — written by a sequential run?); resume with \
                             --chain-method sequential"
                                .into(),
                        ));
                    }
                    cs
                }
                _ => (0..num_chains)
                    .map(|k| {
                        let (init_z, chain_opts) = chain_start(dim, opts, k);
                        ChainCursor::new(&init_z, &chain_opts)
                    })
                    .collect(),
            };
            let save_path = cfg.path.clone();
            let o = opts.clone();
            let mut sink = |cs: &[ChainCursor]| match &save_path {
                Some(p) => save_chain_checkpoint(p, &o, dim, cs),
                None => Ok(()),
            };
            // same engine selection as run_compiled_chains_method: the
            // tiled massive-lane potential past the lane threshold,
            // bitwise-identical either way (rust/tests/lane_scaling.rs)
            let (warmup_secs, sample_secs, completed) =
                if num_chains > crate::coordinator::TILED_LANE_THRESHOLD {
                    let threads = std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1);
                    let tile = crate::mcmc::auto_tile_width(num_chains, threads);
                    let mut pot =
                        crate::compile::tiled_from_layout(model, &layout, num_chains, tile);
                    run_chains_vectorized_from(
                        &mut pot,
                        opts,
                        max_tree_depth,
                        &mut cursors,
                        cfg.deadline(),
                        cfg.every,
                        &mut sink,
                    )?
                } else {
                    let mut pot =
                        BatchedCompiledModel::new(model.clone(), layout.clone(), num_chains);
                    run_chains_vectorized_from(
                        &mut pot,
                        opts,
                        max_tree_depth,
                        &mut cursors,
                        cfg.deadline(),
                        cfg.every,
                        &mut sink,
                    )?
                };
            if let Some(p) = &cfg.path {
                save_chain_checkpoint(p, opts, dim, &cursors)?;
            }
            let results = cursors
                .into_iter()
                .map(|c| c.into_result(warmup_secs, sample_secs))
                .collect();
            Ok((layout, results, completed))
        }
    }
}

// ---------------------------------------------------------------------
// SVI checkpoints
// ---------------------------------------------------------------------

/// Serialize an SVI step-boundary snapshot atomically.
pub fn save_svi_checkpoint(
    path: &Path,
    seed: u64,
    num_steps: usize,
    cur: &SviCursor,
) -> Result<()> {
    let mut o = std::collections::BTreeMap::new();
    o.insert("format".into(), Json::Str("fugue-svi-checkpoint".into()));
    o.insert("version".into(), Json::Num(1.0));
    o.insert("dim".into(), Json::Num((cur.params.len() / 2) as f64));
    o.insert("num_steps".into(), Json::Num(num_steps as f64));
    o.insert("seed".into(), enc_u64(seed));
    o.insert("params".into(), enc_f64s(&cur.params));
    o.insert(
        "opt_moments".into(),
        Json::Arr(cur.opt_moments.iter().map(|m| enc_f64s(m)).collect()),
    );
    o.insert("opt_t".into(), enc_u64(cur.opt_t));
    o.insert(
        "rng_s".into(),
        Json::Arr(cur.rng_s.iter().map(|&w| enc_u64(w)).collect()),
    );
    o.insert("rng_spare".into(), cur.rng_spare.map_or(Json::Null, enc_f64));
    o.insert("elbo_trace".into(), enc_f64s(&cur.elbo_trace));
    o.insert("avg_params".into(), enc_f64s(&cur.avg_params));
    o.insert("avg_count".into(), enc_u64(cur.avg_count));
    o.insert("backoff".into(), enc_f64(cur.backoff));
    o.insert("skipped".into(), enc_u64(cur.skipped));
    // minibatch-scheduler state: written only by subsampled runs, so
    // full-batch checkpoints keep the exact pre-subsampling schema
    if let Some(sc) = &cur.subsample {
        let mut s = std::collections::BTreeMap::new();
        s.insert("epoch".into(), enc_u64(sc.epoch));
        s.insert("pos".into(), Json::Num(sc.pos as f64));
        s.insert(
            "rng_s".into(),
            Json::Arr(sc.rng_s.iter().map(|&w| enc_u64(w)).collect()),
        );
        s.insert("rng_spare".into(), sc.rng_spare.map_or(Json::Null, enc_f64));
        o.insert("subsample".into(), Json::Obj(s));
    }
    write_atomic(path, &Json::Obj(o).to_string_pretty())
}

/// Load an SVI checkpoint, validating dimension/step-count/seed against
/// the resuming run.
pub fn load_svi_checkpoint(
    path: &Path,
    seed: u64,
    num_steps: usize,
    dim: usize,
) -> Result<SviCursor> {
    let root = load_root(path, "fugue-svi-checkpoint")?;
    check_cfg(path, "dim", dim as u64, root.get("dim").and_then(|v| v.as_f64()).map(|n| n as u64))?;
    check_cfg(
        path,
        "num_steps",
        num_steps as u64,
        root.get("num_steps").and_then(|v| v.as_f64()).map(|n| n as u64),
    )?;
    check_cfg(path, "seed", seed, root.get("seed").and_then(dec_u64))?;
    let rng_s_v = field(&root, "rng_s", path, |v| {
        v.as_arr()?.iter().map(dec_u64).collect::<Option<Vec<u64>>>()
    })?;
    if rng_s_v.len() != 4 {
        return Err(ck_err(path, "rng_s must have 4 words".into()));
    }
    let rng_spare = match root.get("rng_spare") {
        Some(Json::Null) | None => None,
        Some(v) => Some(
            dec_f64(v).ok_or_else(|| ck_err(path, "malformed field 'rng_spare'".into()))?,
        ),
    };
    let opt_moments = field(&root, "opt_moments", path, |v| {
        v.as_arr()?.iter().map(dec_f64s).collect::<Option<Vec<Vec<f64>>>>()
    })?;
    // absent in pre-subsampling checkpoints → full-batch resume
    let subsample = match root.get("subsample") {
        Some(Json::Null) | None => None,
        Some(sj) => {
            let s_rng = field(sj, "rng_s", path, |v| {
                v.as_arr()?.iter().map(dec_u64).collect::<Option<Vec<u64>>>()
            })?;
            if s_rng.len() != 4 {
                return Err(ck_err(path, "subsample rng_s must have 4 words".into()));
            }
            let spare = match sj.get("rng_spare") {
                Some(Json::Null) | None => None,
                Some(v) => Some(
                    dec_f64(v)
                        .ok_or_else(|| ck_err(path, "malformed subsample 'rng_spare'".into()))?,
                ),
            };
            Some(crate::data::stream::SubsampleCursor {
                epoch: field(sj, "epoch", path, dec_u64)?,
                pos: field(sj, "pos", path, |v| v.as_usize())?,
                rng_s: [s_rng[0], s_rng[1], s_rng[2], s_rng[3]],
                rng_spare: spare,
            })
        }
    };
    Ok(SviCursor {
        params: field(&root, "params", path, dec_f64s)?,
        opt_moments,
        opt_t: field(&root, "opt_t", path, dec_u64)?,
        rng_s: [rng_s_v[0], rng_s_v[1], rng_s_v[2], rng_s_v[3]],
        rng_spare,
        elbo_trace: field(&root, "elbo_trace", path, dec_f64s)?,
        avg_params: field(&root, "avg_params", path, dec_f64s)?,
        avg_count: field(&root, "avg_count", path, dec_u64)?,
        backoff: field(&root, "backoff", path, dec_f64)?,
        skipped: field(&root, "skipped", path, dec_u64)?,
        subsample,
    })
}

/// Compile a model and fit it with the native SVI engine under
/// checkpoint/resume and a wall-clock budget — the fault-contained twin
/// of [`crate::coordinator::run_svi_native`], bitwise-identical to it
/// (and to an interrupted + resumed invocation of itself) step for
/// step.
pub fn run_svi_checkpointed<M: EffModel + Clone + Send>(
    model: &M,
    opts: &SviOptions,
    cfg: &CheckpointConfig,
) -> Result<(SiteLayout, NativeSviResult)> {
    anyhow::ensure!(opts.num_particles > 0, "SVI needs at least one ELBO particle");
    let layout = SiteLayout::trace(model, opts.seed)?;
    let save_path = cfg.path.clone();
    let (seed, num_steps) = (opts.seed, opts.num_steps);
    let mut sink = move |cur: &SviCursor| match &save_path {
        Some(p) => save_svi_checkpoint(p, seed, num_steps, cur),
        None => Ok(()),
    };
    fn restore_into<E: crate::svi::native::ElboEngine>(
        svi: &mut NativeSvi<E>,
        cfg: &CheckpointConfig,
        seed: u64,
        num_steps: usize,
        dim: usize,
    ) -> Result<()> {
        if let Some(p) = &cfg.path {
            if cfg.resume && p.exists() {
                let cur = load_svi_checkpoint(p, seed, num_steps, dim)?;
                svi.import_cursor(&cur)?;
            }
        }
        Ok(())
    }
    let result = if opts.vectorize_particles
        && opts.num_particles > crate::coordinator::TILED_LANE_THRESHOLD
    {
        // engine parity with run_svi_native: tiled massive-lane
        // particles past the threshold (bitwise-identical either way)
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let tile = crate::mcmc::auto_tile_width(opts.num_particles, threads);
        let pot = crate::compile::tiled_from_layout(model, &layout, opts.num_particles, tile);
        let mut svi = NativeSvi::new(BatchedParticles::new(pot), opts)?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    } else if opts.vectorize_particles && opts.num_particles > 1 {
        let pot = BatchedCompiledModel::new(model.clone(), layout.clone(), opts.num_particles);
        let mut svi = NativeSvi::new(BatchedParticles::new(pot), opts)?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    } else {
        let pot = CompiledModel::new(model.clone(), layout.clone());
        let mut svi = NativeSvi::new(ScalarParticles::new(pot, opts.num_particles), opts)?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    };
    Ok((layout, result))
}

/// [`run_svi_checkpointed`] for **subsampled** models — the
/// checkpointed twin of [`crate::coordinator::run_svi_subsampled`].
/// The minibatch scheduler's cursor rides the `subsample` object of the
/// SVI checkpoint, so an interrupted + resumed run walks the exact same
/// epoch permutations and minibatch sequence as an uninterrupted one.
pub fn run_svi_subsampled_checkpointed<M: SubsampledModel + Clone + Send>(
    model: &M,
    opts: &SviOptions,
    cfg: &CheckpointConfig,
) -> Result<(SiteLayout, NativeSviResult)> {
    anyhow::ensure!(opts.num_particles > 0, "SVI needs at least one ELBO particle");
    let (total, batch) = (model.total_rows(), model.batch_rows());
    let sched = MinibatchScheduler::new(total, batch, scheduler_rng(opts.seed));
    let layout = SiteLayout::trace(model, opts.seed)?;
    let save_path = cfg.path.clone();
    let (seed, num_steps) = (opts.seed, opts.num_steps);
    let mut sink = move |cur: &SviCursor| match &save_path {
        Some(p) => save_svi_checkpoint(p, seed, num_steps, cur),
        None => Ok(()),
    };
    fn restore_into<E: crate::svi::native::ElboEngine>(
        svi: &mut NativeSvi<E>,
        cfg: &CheckpointConfig,
        seed: u64,
        num_steps: usize,
        dim: usize,
    ) -> Result<()> {
        if let Some(p) = &cfg.path {
            if cfg.resume && p.exists() {
                let cur = load_svi_checkpoint(p, seed, num_steps, dim)?;
                svi.import_cursor(&cur)?;
            }
        }
        Ok(())
    }
    let result = if opts.vectorize_particles
        && opts.num_particles > crate::coordinator::TILED_LANE_THRESHOLD
    {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let tile = crate::mcmc::auto_tile_width(opts.num_particles, threads);
        let pot = crate::compile::tiled_from_layout(model, &layout, opts.num_particles, tile);
        let mut svi = NativeSvi::new(SubsampledBatchedParticles::new(pot, sched), opts)?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    } else if opts.vectorize_particles && opts.num_particles > 1 {
        let pot = BatchedCompiledModel::new(model.clone(), layout.clone(), opts.num_particles);
        let mut svi = NativeSvi::new(SubsampledBatchedParticles::new(pot, sched), opts)?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    } else {
        let pot = CompiledModel::new(model.clone(), layout.clone());
        let mut svi = NativeSvi::new(
            SubsampledScalarParticles::new(pot, opts.num_particles, sched),
            opts,
        )?;
        restore_into(&mut svi, cfg, seed, num_steps, layout.dim)?;
        svi.run_with(cfg.deadline(), cfg.every, &mut sink)?
    };
    Ok((layout, result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain::run_chains;
    use crate::mcmc::Potential;

    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    fn opts() -> NutsOptions {
        NutsOptions {
            num_warmup: 60,
            num_samples: 80,
            seed: 17,
            ..Default::default()
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("fugue-ckpt-test-{}-{}", std::process::id(), name));
        p
    }

    #[test]
    fn cursor_json_roundtrip_is_bitwise() {
        let o = opts();
        let (init_z, chain_opts) = chain_start(2, &o, 0);
        let mut cur = ChainCursor::new(&init_z, &chain_opts);
        // dirty the state so the roundtrip covers a non-trivial snapshot
        cur.i = 7;
        cur.z = vec![0.25, f64::NAN];
        cur.rng.normal();
        cur.da.update(0.7);
        cur.welford.update(&[1.0, -2.0]);
        cur.stats.accept_prob = vec![0.5; 7];
        cur.stats.num_leapfrog = vec![3; 7];
        cur.stats.potential = vec![f64::INFINITY; 7];
        cur.stats.diverging = vec![true; 7];
        cur.stats.depth = vec![2; 7];
        cur.samples = vec![1.0, 2.0];
        cur.divergences = 3;
        cur.quarantines = 1;
        let j = cursor_to_json(&cur);
        let back = cursor_from_json(&j, Path::new("test"), 2).unwrap();
        assert_eq!(back.i, cur.i);
        assert_eq!(back.z[0].to_bits(), cur.z[0].to_bits());
        assert!(back.z[1].is_nan());
        assert_eq!(back.rng.state(), cur.rng.state());
        assert_eq!(back.da.state(), cur.da.state());
        assert_eq!(back.welford.mean, cur.welford.mean);
        assert_eq!(back.welford.count, cur.welford.count);
        assert_eq!(back.stats.potential[0], f64::INFINITY);
        assert_eq!(back.divergences, 3);
        assert_eq!(back.quarantines, 1);
    }

    #[test]
    fn checkpointed_run_matches_plain_run_bitwise() {
        let mut s1 = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let plain = run_chains(&mut s1, 2, &opts()).unwrap();

        let mut s2 = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let cfg = CheckpointConfig::default();
        let (ckpt, completed) = run_chains_checkpointed(&mut s2, 2, &opts(), &cfg).unwrap();
        assert!(completed);
        for (a, b) in plain.iter().zip(&ckpt) {
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.step_size, b.step_size);
            assert_eq!(a.inv_mass, b.inv_mass);
            assert_eq!(a.stats.accept_prob, b.stats.accept_prob);
        }
    }

    #[test]
    fn save_load_resume_is_bitwise_identical() {
        let path = tmp_path("resume");
        let o = opts();
        let mut s1 = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let plain = run_chains(&mut s1, 2, &o).unwrap();

        // run half the draws, checkpoint, then resume in a fresh runner
        let half = o.clone();
        let schedule = WarmupSchedule::build(o.num_warmup);
        let closes = schedule.window_closes();
        let mut s2 = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let starts: Vec<_> = (0..2).map(|c| chain_start(2, &half, c)).collect();
        let mut cursors: Vec<ChainCursor> =
            starts.iter().map(|(z, co)| ChainCursor::new(z, co)).collect();
        for _ in 0..70 {
            advance_chain(&mut s2, &mut cursors[0], &starts[0].1, &schedule, &closes).unwrap();
        }
        save_chain_checkpoint(&path, &o, 2, &cursors).unwrap();

        let mut s3 = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let cfg = CheckpointConfig {
            path: Some(path.clone()),
            resume: true,
            every: 0,
            max_seconds: None,
        };
        let (resumed, completed) = run_chains_checkpointed(&mut s3, 2, &o, &cfg).unwrap();
        assert!(completed);
        for (a, b) in plain.iter().zip(&resumed) {
            assert_eq!(a.samples, b.samples, "resume broke bitwise identity");
            assert_eq!(a.step_size, b.step_size);
            assert_eq!(a.inv_mass, b.inv_mass);
            assert_eq!(a.stats.accept_prob, b.stats.accept_prob);
            assert_eq!(a.divergences, b.divergences);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mismatched_config_is_refused() {
        let path = tmp_path("mismatch");
        let o = opts();
        let starts: Vec<_> = (0..2).map(|c| chain_start(2, &o, c)).collect();
        let cursors: Vec<ChainCursor> =
            starts.iter().map(|(z, co)| ChainCursor::new(z, co)).collect();
        save_chain_checkpoint(&path, &o, 2, &cursors).unwrap();

        let other = NutsOptions { seed: 999, ..o.clone() };
        let err = load_chain_checkpoint(&path, &other, 2, 2).unwrap_err();
        assert!(format!("{err}").contains("seed"), "{err}");
        let err = load_chain_checkpoint(&path, &o, 3, 2).unwrap_err();
        assert!(format!("{err}").contains("num_chains"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn budget_degrades_to_partial_results() {
        let mut s = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let cfg = CheckpointConfig {
            path: None,
            resume: false,
            every: 0,
            max_seconds: Some(0.0),
        };
        let (results, completed) = run_chains_checkpointed(&mut s, 2, &opts(), &cfg).unwrap();
        assert!(!completed, "a zero budget must truncate the run");
        let total: usize = results.iter().map(|r| r.stats.accept_prob.len()).sum();
        assert!(total < 2 * (60 + 80), "ran {total} draws on a zero budget");
    }
}
