//! Parallel multi-chain execution on `std::thread` scoped threads.
//!
//! Each chain gets its own sampler instance (from a caller-supplied
//! factory — potentials own mutable scratch, so they cannot be shared)
//! and its own RNG stream derived deterministically from the base seed
//! by [`chain_start`].  Chains are partitioned over at most
//! `max_threads` workers, and because every chain is fully
//! self-contained the results are **bitwise identical** to the
//! sequential [`crate::coordinator::run_chains`] — independent of
//! thread count and OS scheduling.

use anyhow::Result;

use crate::compile::{CompiledModel, EffModel, SiteLayout};
use crate::coordinator::chain::{chain_start, run_chain, ChainResult, NutsOptions};
use crate::coordinator::sampler::{NativeSampler, Sampler, TreeAlgorithm};

/// Runs N chains across scoped worker threads.
pub struct ParallelChainRunner {
    pub num_chains: usize,
    /// worker-thread cap (defaults to the machine's parallelism)
    pub max_threads: usize,
}

impl ParallelChainRunner {
    pub fn new(num_chains: usize) -> ParallelChainRunner {
        let max_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ParallelChainRunner {
            num_chains,
            max_threads,
        }
    }

    pub fn with_threads(num_chains: usize, max_threads: usize) -> ParallelChainRunner {
        ParallelChainRunner {
            num_chains,
            max_threads: max_threads.max(1),
        }
    }

    /// Run all chains; `make_sampler(c)` builds chain `c`'s sampler
    /// inside its worker thread.  Results come back in chain order.
    pub fn run<S, F>(&self, make_sampler: F, opts: &NutsOptions) -> Result<Vec<ChainResult>>
    where
        S: Sampler,
        F: Fn(usize) -> Result<S> + Sync,
    {
        let num_chains = self.num_chains;
        if num_chains == 0 {
            return Ok(Vec::new());
        }
        let threads = self.max_threads.max(1).min(num_chains);
        let per = num_chains.div_ceil(threads);

        let mut slots: Vec<Option<Result<ChainResult>>> = Vec::new();
        slots.resize_with(num_chains, || None);
        let make = &make_sampler;
        std::thread::scope(|scope| {
            for (w, chunk) in slots.chunks_mut(per).enumerate() {
                let base = w * per;
                scope.spawn(move || {
                    for (i, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(run_single(make, base + i, opts));
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.expect("worker filled every chain slot"))
            .collect()
    }
}

fn run_single<S, F>(make_sampler: &F, c: usize, opts: &NutsOptions) -> Result<ChainResult>
where
    S: Sampler,
    F: Fn(usize) -> Result<S> + Sync,
{
    let mut sampler = make_sampler(c)?;
    let (init_z, chain_opts) = chain_start(sampler.dim(), opts, c);
    run_chain(&mut sampler, &init_z, &chain_opts)
}

/// Compile an effect-handler program and run `num_chains` parallel
/// iterative-NUTS chains over it — model source to posterior draws in
/// one call, no hand-written gradients anywhere.
///
/// The discovery pass runs exactly once; each worker thread then gets
/// its own [`CompiledModel`] over the shared layout (potentials own
/// mutable tape/scratch state, so they cannot be shared), keeping
/// chains fully independent and the results bitwise identical to a
/// sequential run with the same options.  Returns the compiled
/// [`SiteLayout`] (for labeling and constraining draws) alongside the
/// per-chain results.
pub fn run_compiled_chains<M: EffModel + Clone + Sync>(
    model: &M,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
) -> Result<(SiteLayout, Vec<ChainResult>)> {
    run_compiled_chains_opt(model, num_chains, max_tree_depth, opts, true)
}

/// [`run_compiled_chains`] with an explicit optimizing-compiler switch:
/// `optimized = false` serves every frozen evaluation from the tape
/// interpreter instead of the fused/re-slotted
/// [`crate::autodiff::OptTapeProgram`].  The two settings are bitwise
/// identical (`rust/tests/tape_opt.rs`); the switch exists for
/// benchmarking and cross-checks.
pub fn run_compiled_chains_opt<M: EffModel + Clone + Sync>(
    model: &M,
    num_chains: usize,
    max_tree_depth: u32,
    opts: &NutsOptions,
    optimized: bool,
) -> Result<(SiteLayout, Vec<ChainResult>)> {
    let layout = SiteLayout::trace(model, opts.seed)?;
    let runner = ParallelChainRunner::new(num_chains);
    let results = runner.run(
        |_c| {
            let mut pot = CompiledModel::new(model.clone(), layout.clone());
            pot.set_optimized(optimized);
            Ok(NativeSampler::new(
                pot,
                TreeAlgorithm::Iterative,
                max_tree_depth,
            ))
        },
        opts,
    )?;
    Ok((layout, results))
}

/// Convenience wrapper: run `num_chains` chains in parallel with the
/// default thread cap.
pub fn run_chains_parallel<S, F>(
    make_sampler: F,
    num_chains: usize,
    opts: &NutsOptions,
) -> Result<Vec<ChainResult>>
where
    S: Sampler,
    F: Fn(usize) -> Result<S> + Sync,
{
    ParallelChainRunner::new(num_chains).run(make_sampler, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain::run_chains;
    use crate::coordinator::sampler::{NativeSampler, TreeAlgorithm};
    use crate::mcmc::Potential;

    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    fn opts() -> NutsOptions {
        NutsOptions {
            num_warmup: 100,
            num_samples: 200,
            seed: 99,
            ..Default::default()
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let make = |_c: usize| Ok(NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10));
        let par = ParallelChainRunner::new(4).run(make, &opts()).unwrap();
        let mut sampler = NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10);
        let seq = run_chains(&mut sampler, 4, &opts()).unwrap();
        assert_eq!(par.len(), seq.len());
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.samples, s.samples);
            assert_eq!(p.step_size, s.step_size);
            assert_eq!(p.inv_mass, s.inv_mass);
            assert_eq!(p.divergences, s.divergences);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let make = |_c: usize| Ok(NativeSampler::new(Gauss, TreeAlgorithm::Iterative, 10));
        let one = ParallelChainRunner::with_threads(3, 1).run(make, &opts()).unwrap();
        let many = ParallelChainRunner::with_threads(3, 8).run(make, &opts()).unwrap();
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.samples, b.samples);
        }
    }
}
