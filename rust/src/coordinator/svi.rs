//! Native SVI coordination: compile an effect-handler program once,
//! pick the particle backend, and run reparameterized ADVI — the SVI
//! twin of [`crate::coordinator::run_compiled_chains_method`].
//!
//! With `vectorize_particles` (the default) the K ELBO particles ride
//! the **same** batched compiler the vectorized chain engine uses
//! ([`BatchedCompiledModel`], K lanes = K particles, one fused frozen
//! [`crate::autodiff::BatchTapeProgram`] sweep per SVI step); otherwise
//! each particle is a scalar [`CompiledModel`] evaluation.  Both paths
//! are bitwise identical under the same seed — only wall-clock differs
//! (`svi_particle_batch_speedup` in BENCH_native.json).

use anyhow::{ensure, Result};

use crate::compile::{
    tiled_from_layout, BatchedCompiledModel, CompiledModel, EffModel, SiteLayout, SubsampledModel,
};
use crate::coordinator::TILED_LANE_THRESHOLD;
use crate::data::stream::MinibatchScheduler;
use crate::mcmc::auto_tile_width;
use crate::svi::native::{BatchedParticles, NativeSvi, NativeSviResult, ScalarParticles, SviOptions};
use crate::svi::subsample::{
    scheduler_rng, SubsampledBatchedParticles, SubsampledScalarParticles,
};

/// Compile `model` and fit a mean-field ADVI posterior with the native
/// engine — the entry point behind the `fugue svi-model` CLI.  Returns
/// the compiled layout (for constrained-space reporting and predictive
/// replay) alongside the fitted guide and ELBO trace.
///
/// Particle counts past [`TILED_LANE_THRESHOLD`] ride the tiled
/// massive-lane potential (K=512 particles → tile-per-thread lanes) —
/// an execution strategy only, bitwise-identical to the single-program
/// backend per particle.
pub fn run_svi_native<M: EffModel + Clone + Send>(
    model: &M,
    opts: &SviOptions,
) -> Result<(SiteLayout, NativeSviResult)> {
    ensure!(opts.num_particles > 0, "SVI needs at least one ELBO particle");
    let layout = SiteLayout::trace(model, opts.seed)?;
    let result = if opts.vectorize_particles && opts.num_particles > TILED_LANE_THRESHOLD {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let tile = auto_tile_width(opts.num_particles, threads);
        let pot = tiled_from_layout(model, &layout, opts.num_particles, tile);
        NativeSvi::new(BatchedParticles::new(pot), opts)?.run()
    } else if opts.vectorize_particles && opts.num_particles > 1 {
        let pot = BatchedCompiledModel::new(model.clone(), layout.clone(), opts.num_particles);
        NativeSvi::new(BatchedParticles::new(pot), opts)?.run()
    } else {
        let pot = CompiledModel::new(model.clone(), layout.clone());
        NativeSvi::new(ScalarParticles::new(pot, opts.num_particles), opts)?.run()
    };
    Ok((layout, result))
}

/// [`run_svi_native`] for **subsampled** models: same backend choice
/// (scalar / fused-lane / tiled particles), plus a deterministic
/// minibatch scheduler ([`scheduler_rng`] stream of `opts.seed`) that
/// swaps the compiled potential's minibatch before every ELBO step.
/// With `model.batch_rows() == model.total_rows()` the scheduler is
/// the identity and the run is bitwise-identical to
/// [`run_svi_native`] on the equivalent full-batch model
/// (`rust/tests/subsampling.rs`).
pub fn run_svi_subsampled<M: SubsampledModel + Clone + Send>(
    model: &M,
    opts: &SviOptions,
) -> Result<(SiteLayout, NativeSviResult)> {
    ensure!(opts.num_particles > 0, "SVI needs at least one ELBO particle");
    let (total, batch) = (model.total_rows(), model.batch_rows());
    let sched = MinibatchScheduler::new(total, batch, scheduler_rng(opts.seed));
    let layout = SiteLayout::trace(model, opts.seed)?;
    let result = if opts.vectorize_particles && opts.num_particles > TILED_LANE_THRESHOLD {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let tile = auto_tile_width(opts.num_particles, threads);
        let pot = tiled_from_layout(model, &layout, opts.num_particles, tile);
        NativeSvi::new(SubsampledBatchedParticles::new(pot, sched), opts)?.run()
    } else if opts.vectorize_particles && opts.num_particles > 1 {
        let pot = BatchedCompiledModel::new(model.clone(), layout.clone(), opts.num_particles);
        NativeSvi::new(SubsampledBatchedParticles::new(pot, sched), opts)?.run()
    } else {
        let pot = CompiledModel::new(model.clone(), layout.clone());
        NativeSvi::new(
            SubsampledScalarParticles::new(pot, opts.num_particles, sched),
            opts,
        )?
        .run()
    };
    Ok((layout, result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::NormalMean;

    fn toy() -> NormalMean {
        NormalMean {
            y: vec![1.0, 2.0, 3.0],
            sigma: 2.0,
        }
    }

    /// Scalar-particle and fused-lane runs with the same options and
    /// seed must be bitwise identical end-to-end — the backend is an
    /// execution strategy, invisible to the statistics.
    #[test]
    fn particle_backends_are_bitwise_identical() {
        let base = SviOptions {
            num_steps: 120,
            num_particles: 4,
            lr: 0.05,
            seed: 9,
            ..Default::default()
        };
        let scalar = SviOptions {
            vectorize_particles: false,
            ..base.clone()
        };
        let (_, a) = run_svi_native(&toy(), &base).unwrap();
        let (_, b) = run_svi_native(&toy(), &scalar).unwrap();
        assert_eq!(a.steps, b.steps);
        for (x, y) in a.elbo_trace.iter().zip(&b.elbo_trace) {
            assert_eq!(x.to_bits(), y.to_bits(), "ELBO trace diverged");
        }
        for (x, y) in a.guide.params().iter().zip(b.guide.params()) {
            assert_eq!(x.to_bits(), y.to_bits(), "guide params diverged");
        }
    }
}
