//! The inference coordinator: chain lifecycle, Stan-style warmup
//! adaptation, multi-chain scheduling, and dispatch accounting.
//!
//! The paper leaves this layer to Python; here it is the L3 Rust
//! service.  The key design point is that the compiled NUTS artifact
//! takes step size and inverse mass matrix as *inputs*, so all
//! adaptation happens host-side between dispatches without recompiling
//! (DESIGN.md §2).
//!
//! When the potential is a compiled effect-handler program, all three
//! chain methods ([`ChainMethod`]: sequential, parallel, vectorized)
//! run on **frozen tape programs**: the model is interpreted once on
//! the first gradient evaluation and every later leapfrog is a flat
//! forward/backward sweep with no handler/`Alg` interpretation (see
//! [`crate::compile::CompiledModel`] /
//! [`crate::compile::BatchedCompiledModel`] and the "Record once,
//! replay many" section of ARCHITECTURE.md).  Freezing is invisible to
//! this layer — frozen and interpreted gradients are bitwise equal —
//! so warmup adaptation, chain scheduling and the cross-method bitwise
//! guarantees are unchanged; `fugue bench` reports the payoff as
//! `frozen_speedup_vs_replay`.
//!
//! The same compiled pieces also serve the second inference engine:
//! [`run_svi_native`] fits a mean-field ADVI posterior by driving the
//! frozen gradients through the reparameterized ELBO
//! ([`crate::svi`]), with the K particles mapped onto the batched
//! compiler's lanes exactly like vectorized chains.

pub mod chain;
pub mod checkpoint;
pub mod parallel;
pub mod sampler;
pub mod svi;
pub mod vectorized;
pub mod warmup;

pub use chain::{
    chain_start, run_chain, run_chains, ChainCursor, ChainResult, ChainStats, NutsOptions,
};
pub use checkpoint::{
    load_chain_checkpoint, load_svi_checkpoint, run_chains_checkpointed,
    run_compiled_chains_checkpointed, run_svi_checkpointed, run_svi_subsampled_checkpointed,
    save_chain_checkpoint, save_svi_checkpoint, CheckpointConfig,
};
pub use parallel::{
    run_chains_parallel, run_compiled_chains, run_compiled_chains_opt, ParallelChainRunner,
};
pub use sampler::{FusedSampler, NativeSampler, Sampler, TreeAlgorithm};
pub use svi::{run_svi_native, run_svi_subsampled};
pub use vectorized::{
    run_chains_vectorized, run_chains_vectorized_from, run_compiled_chains_method,
    run_compiled_chains_method_opt, ChainMethod, TILED_LANE_THRESHOLD,
};
pub use warmup::WarmupSchedule;
