//! The inference coordinator: chain lifecycle, Stan-style warmup
//! adaptation, multi-chain scheduling, and dispatch accounting.
//!
//! The paper leaves this layer to Python; here it is the L3 Rust
//! service.  The key design point is that the compiled NUTS artifact
//! takes step size and inverse mass matrix as *inputs*, so all
//! adaptation happens host-side between dispatches without recompiling
//! (DESIGN.md §2).

pub mod chain;
pub mod parallel;
pub mod sampler;
pub mod vectorized;
pub mod warmup;

pub use chain::{chain_start, run_chain, run_chains, ChainResult, ChainStats, NutsOptions};
pub use parallel::{run_chains_parallel, run_compiled_chains, ParallelChainRunner};
pub use sampler::{FusedSampler, NativeSampler, Sampler, TreeAlgorithm};
pub use vectorized::{run_chains_vectorized, run_compiled_chains_method, ChainMethod};
pub use warmup::WarmupSchedule;
