//! Distributions over a generic scalar algebra — the model compiler's
//! counterpart of [`crate::ppl::dist::Dist`].
//!
//! A [`DistV<V>`] carries its parameters as algebra values `V`
//! ([`f64`] in the trace pass, [`crate::autodiff::Var`] in the
//! evaluation pass), so a latent scale parameter feeding a downstream
//! likelihood stays differentiable end-to-end.  Shape-like parameters
//! whose log-normalizers need `ln Γ` (Gamma/InverseGamma concentration,
//! Beta exponents) are plain `f64` constants: they cannot be latent,
//! which matches what the tape can differentiate.
//!
//! `log_prob` is written purely in terms of [`Alg`] operations, so the
//! two value domains agree bitwise; `rust/tests/compiled_model.rs`
//! cross-checks the `f64` instantiation against [`Dist::log_prob`].

use crate::autodiff::Alg;
use crate::ppl::dist::{Dist, Support};
use crate::ppl::special::{ln_beta, ln_gamma, LN_2PI};

/// A distribution with algebra-valued parameters.  `V` is `f64` during
/// tracing and a tape [`crate::autodiff::Var`] during potential
/// evaluation.
#[derive(Debug, Clone, Copy)]
pub enum DistV<V> {
    Normal { loc: V, scale: V },
    HalfNormal { scale: V },
    Cauchy { loc: V, scale: V },
    HalfCauchy { scale: V },
    Exponential { rate: V },
    LogNormal { loc: V, scale: V },
    Uniform { low: f64, high: f64 },
    Gamma { concentration: f64, rate: V },
    InverseGamma { concentration: f64, rate: V },
    Beta { a: f64, b: f64 },
    BernoulliLogits { logits: V },
}

impl<V: Copy + std::fmt::Debug> DistV<V> {
    /// Support declaration; drives the site's unconstraining transform.
    pub fn support(&self) -> Support {
        use DistV::*;
        match self {
            Normal { .. } | Cauchy { .. } => Support::Real,
            HalfNormal { .. }
            | HalfCauchy { .. }
            | Exponential { .. }
            | LogNormal { .. }
            | Gamma { .. }
            | InverseGamma { .. } => Support::Positive,
            Uniform { .. } | Beta { .. } => Support::UnitInterval,
            BernoulliLogits { .. } => Support::Discrete,
        }
    }

    /// Bounds when the support is a bounded interval (drives the
    /// affine-sigmoid transform for `Uniform`).
    pub fn interval(&self) -> Option<(f64, f64)> {
        match self {
            DistV::Uniform { low, high } => Some((*low, *high)),
            DistV::Beta { .. } => Some((0.0, 1.0)),
            _ => None,
        }
    }

    /// Log-density at `x`, evaluated over the algebra `alg`.  `x` must
    /// lie in the support (the compiler guarantees this by construction:
    /// latent values come out of the constraining transform, observed
    /// values are validated data).
    pub fn log_prob<A: Alg<V = V>>(&self, alg: &mut A, x: V) -> V {
        use DistV::*;
        match *self {
            Normal { loc, scale } => {
                let d = alg.sub(x, loc);
                let z = alg.div(d, scale);
                let z2 = alg.square(z);
                let t = alg.scale(z2, -0.5);
                let ls = alg.ln(scale);
                let t2 = alg.sub(t, ls);
                alg.offset(t2, -0.5 * LN_2PI)
            }
            HalfNormal { scale } => {
                let z = alg.div(x, scale);
                let z2 = alg.square(z);
                let t = alg.scale(z2, -0.5);
                let ls = alg.ln(scale);
                let t2 = alg.sub(t, ls);
                alg.offset(t2, std::f64::consts::LN_2 - 0.5 * LN_2PI)
            }
            Cauchy { loc, scale } => {
                let d = alg.sub(x, loc);
                let z = alg.div(d, scale);
                let z2 = alg.square(z);
                let l1 = alg.log1p(z2);
                let ls = alg.ln(scale);
                let s = alg.add(l1, ls);
                let n = alg.neg(s);
                alg.offset(n, -std::f64::consts::PI.ln())
            }
            HalfCauchy { scale } => {
                let z = alg.div(x, scale);
                let z2 = alg.square(z);
                let l1 = alg.log1p(z2);
                let ls = alg.ln(scale);
                let s = alg.add(l1, ls);
                let n = alg.neg(s);
                alg.offset(n, std::f64::consts::LN_2 - std::f64::consts::PI.ln())
            }
            Exponential { rate } => {
                let lr = alg.ln(rate);
                let rx = alg.mul(rate, x);
                alg.sub(lr, rx)
            }
            LogNormal { loc, scale } => {
                let lx = alg.ln(x);
                let d = alg.sub(lx, loc);
                let z = alg.div(d, scale);
                let z2 = alg.square(z);
                let t = alg.scale(z2, -0.5);
                let ls = alg.ln(scale);
                let t1 = alg.sub(t, ls);
                let t2 = alg.sub(t1, lx);
                alg.offset(t2, -0.5 * LN_2PI)
            }
            Uniform { low, high } => alg.lit(-(high - low).ln()),
            Gamma {
                concentration: c,
                rate,
            } => {
                let lr = alg.ln(rate);
                let t1 = alg.scale(lr, c);
                let lx = alg.ln(x);
                let t2 = alg.scale(lx, c - 1.0);
                let rx = alg.mul(rate, x);
                let s = alg.add(t1, t2);
                let s2 = alg.sub(s, rx);
                alg.offset(s2, -ln_gamma(c))
            }
            InverseGamma {
                concentration: c,
                rate,
            } => {
                let lr = alg.ln(rate);
                let t1 = alg.scale(lr, c);
                let lx = alg.ln(x);
                let t2 = alg.scale(lx, -(c + 1.0));
                let q = alg.div(rate, x);
                let s = alg.add(t1, t2);
                let s2 = alg.sub(s, q);
                alg.offset(s2, -ln_gamma(c))
            }
            Beta { a, b } => {
                let lx = alg.ln(x);
                let t1 = alg.scale(lx, a - 1.0);
                let nx = alg.neg(x);
                let l1 = alg.log1p(nx);
                let t2 = alg.scale(l1, b - 1.0);
                let s = alg.add(t1, t2);
                alg.offset(s, -ln_beta(a, b))
            }
            BernoulliLogits { logits } => {
                let p = alg.mul(x, logits);
                let sp = alg.softplus(logits);
                alg.sub(p, sp)
            }
        }
    }
}

impl DistV<f64> {
    /// The plain-`f64` instantiation as a [`Dist`] (sampler + reference
    /// density): the trace pass draws prior values through this.
    pub fn to_dist(&self) -> Dist {
        use DistV::*;
        match *self {
            Normal { loc, scale } => Dist::Normal { loc, scale },
            HalfNormal { scale } => Dist::HalfNormal { scale },
            Cauchy { loc, scale } => Dist::Cauchy { loc, scale },
            HalfCauchy { scale } => Dist::HalfCauchy { scale },
            Exponential { rate } => Dist::Exponential { rate },
            LogNormal { loc, scale } => Dist::LogNormal { loc, scale },
            Uniform { low, high } => Dist::Uniform { low, high },
            Gamma {
                concentration,
                rate,
            } => Dist::Gamma {
                concentration,
                rate,
            },
            InverseGamma {
                concentration,
                rate,
            } => Dist::InverseGamma {
                concentration,
                rate,
            },
            Beta { a, b } => Dist::Beta { a, b },
            BernoulliLogits { logits } => Dist::BernoulliLogits { logits },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::F64Alg;

    /// Every `DistV` density must agree with the reference `Dist`
    /// density at interior points of the support.
    #[test]
    fn matches_reference_densities() {
        let mut a = F64Alg;
        let cases: Vec<(DistV<f64>, f64)> = vec![
            (
                DistV::Normal {
                    loc: 0.4,
                    scale: 1.7,
                },
                -0.3,
            ),
            (DistV::HalfNormal { scale: 0.8 }, 1.1),
            (
                DistV::Cauchy {
                    loc: -1.0,
                    scale: 2.0,
                },
                0.7,
            ),
            (DistV::HalfCauchy { scale: 5.0 }, 3.2),
            (DistV::Exponential { rate: 1.4 }, 0.9),
            (
                DistV::LogNormal {
                    loc: 0.2,
                    scale: 0.6,
                },
                1.5,
            ),
            (
                DistV::Uniform {
                    low: -2.0,
                    high: 3.0,
                },
                0.0,
            ),
            (
                DistV::Gamma {
                    concentration: 3.0,
                    rate: 2.0,
                },
                1.2,
            ),
            (
                DistV::InverseGamma {
                    concentration: 3.0,
                    rate: 1.0,
                },
                0.4,
            ),
            (DistV::Beta { a: 2.5, b: 1.5 }, 0.3),
            (DistV::BernoulliLogits { logits: 0.7 }, 1.0),
        ];
        for (d, x) in cases {
            let got = d.log_prob(&mut a, x);
            let want = d.to_dist().log_prob(&[x]);
            assert!(
                (got - want).abs() < 1e-12,
                "{d:?} at {x}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn supports_and_intervals() {
        let n = DistV::Normal {
            loc: 0.0f64,
            scale: 1.0,
        };
        assert_eq!(n.support(), Support::Real);
        assert_eq!(n.interval(), None);
        let u = DistV::<f64>::Uniform {
            low: -1.0,
            high: 2.0,
        };
        assert_eq!(u.support(), Support::UnitInterval);
        assert_eq!(u.interval(), Some((-1.0, 2.0)));
        let b = DistV::<f64>::Beta { a: 2.0, b: 3.0 };
        assert_eq!(b.interval(), Some((0.0, 1.0)));
        let hc = DistV::HalfCauchy { scale: 1.0f64 };
        assert_eq!(hc.support(), Support::Positive);
    }
}
