//! Rust distribution library (the native-pipeline counterpart of
//! `python/compile/minippl/distributions.py`).
//!
//! Values are `Vec<f64>`-shaped (scalars are length-1); every
//! distribution exposes a density and a sampler so the Rust effect
//! handlers ([`crate::effects`]) can run full models natively.  The
//! densities are kept numerically identical to the Python side — the
//! cross-language agreement tests in `rust/tests/` rely on it.

use crate::ppl::special::{ln_beta, ln_gamma, log_sum_exp, sigmoid, softplus, LN_2PI};
use crate::rng::Rng;

/// Support declaration; drives the unconstraining transform in
/// [`crate::ppl::transforms`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Support {
    Real,
    Positive,
    UnitInterval,
    Simplex,
    /// Discrete (no transform; not sampled by NUTS).
    Discrete,
}

/// A univariate or small-multivariate distribution.
#[derive(Debug, Clone)]
pub enum Dist {
    Normal { loc: f64, scale: f64 },
    HalfNormal { scale: f64 },
    Cauchy { loc: f64, scale: f64 },
    HalfCauchy { scale: f64 },
    Exponential { rate: f64 },
    Gamma { concentration: f64, rate: f64 },
    InverseGamma { concentration: f64, rate: f64 },
    Beta { a: f64, b: f64 },
    Uniform { low: f64, high: f64 },
    LogNormal { loc: f64, scale: f64 },
    BernoulliLogits { logits: f64 },
    Categorical { probs: Vec<f64> },
    Dirichlet { concentration: Vec<f64> },
}

impl Dist {
    pub fn support(&self) -> Support {
        use Dist::*;
        match self {
            Normal { .. } | Cauchy { .. } => Support::Real,
            HalfNormal { .. }
            | HalfCauchy { .. }
            | Exponential { .. }
            | Gamma { .. }
            | InverseGamma { .. }
            | LogNormal { .. } => Support::Positive,
            Beta { .. } => Support::UnitInterval,
            Uniform { .. } => Support::UnitInterval, // via affine in transforms
            BernoulliLogits { .. } | Categorical { .. } => Support::Discrete,
            Dirichlet { .. } => Support::Simplex,
        }
    }

    /// Dimensionality of one draw.
    pub fn event_len(&self) -> usize {
        match self {
            Dist::Dirichlet { concentration } => concentration.len(),
            _ => 1,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        use Dist::*;
        match self {
            Normal { loc, scale } => vec![rng.normal_with(*loc, *scale)],
            HalfNormal { scale } => vec![(rng.normal() * scale).abs()],
            Cauchy { loc, scale } => vec![rng.cauchy(*loc, *scale)],
            HalfCauchy { scale } => vec![rng.half_cauchy(*scale)],
            Exponential { rate } => vec![rng.exponential(*rate)],
            Gamma {
                concentration,
                rate,
            } => vec![rng.gamma_rate(*concentration, *rate)],
            InverseGamma {
                concentration,
                rate,
            } => vec![rng.inverse_gamma(*concentration, *rate)],
            Beta { a, b } => vec![rng.beta(*a, *b)],
            Uniform { low, high } => vec![rng.uniform_in(*low, *high)],
            LogNormal { loc, scale } => vec![rng.normal_with(*loc, *scale).exp()],
            BernoulliLogits { logits } => {
                vec![if rng.bernoulli(sigmoid(*logits)) { 1.0 } else { 0.0 }]
            }
            Categorical { probs } => vec![rng.categorical(probs) as f64],
            Dirichlet { concentration } => rng.dirichlet(concentration),
        }
    }

    /// Log-density of one draw (summed over the event for Dirichlet).
    pub fn log_prob(&self, value: &[f64]) -> f64 {
        use Dist::*;
        match self {
            Normal { loc, scale } => {
                let z = (value[0] - loc) / scale;
                -0.5 * z * z - scale.ln() - 0.5 * LN_2PI
            }
            HalfNormal { scale } => {
                if value[0] < 0.0 {
                    return f64::NEG_INFINITY;
                }
                let z = value[0] / scale;
                2f64.ln() - 0.5 * z * z - scale.ln() - 0.5 * LN_2PI
            }
            Cauchy { loc, scale } => {
                let z = (value[0] - loc) / scale;
                -std::f64::consts::PI.ln() - scale.ln() - (z * z).ln_1p()
            }
            HalfCauchy { scale } => {
                if value[0] < 0.0 {
                    return f64::NEG_INFINITY;
                }
                let z = value[0] / scale;
                2f64.ln() - std::f64::consts::PI.ln() - scale.ln() - (z * z).ln_1p()
            }
            Exponential { rate } => {
                if value[0] < 0.0 {
                    return f64::NEG_INFINITY;
                }
                rate.ln() - rate * value[0]
            }
            Gamma {
                concentration: a,
                rate: b,
            } => {
                if value[0] <= 0.0 {
                    return f64::NEG_INFINITY;
                }
                a * b.ln() + (a - 1.0) * value[0].ln() - b * value[0] - ln_gamma(*a)
            }
            InverseGamma {
                concentration: a,
                rate: b,
            } => {
                if value[0] <= 0.0 {
                    return f64::NEG_INFINITY;
                }
                a * b.ln() - (a + 1.0) * value[0].ln() - b / value[0] - ln_gamma(*a)
            }
            Beta { a, b } => {
                let x = value[0];
                if !(0.0..=1.0).contains(&x) {
                    return f64::NEG_INFINITY;
                }
                (a - 1.0) * x.ln() + (b - 1.0) * (-x).ln_1p() - ln_beta(*a, *b)
            }
            Uniform { low, high } => {
                if value[0] < *low || value[0] > *high {
                    f64::NEG_INFINITY
                } else {
                    -(high - low).ln()
                }
            }
            LogNormal { loc, scale } => {
                let x = value[0];
                if x <= 0.0 {
                    return f64::NEG_INFINITY;
                }
                let z = (x.ln() - loc) / scale;
                -0.5 * z * z - scale.ln() - 0.5 * LN_2PI - x.ln()
            }
            BernoulliLogits { logits } => value[0] * logits - softplus(*logits),
            Categorical { probs } => {
                let idx = value[0] as usize;
                let logps: Vec<f64> = probs.iter().map(|p| p.ln()).collect();
                logps[idx] - log_sum_exp(&logps)
            }
            Dirichlet { concentration } => {
                let a = concentration;
                let norm: f64 =
                    a.iter().map(|&ai| ln_gamma(ai)).sum::<f64>() - ln_gamma(a.iter().sum());
                a.iter()
                    .zip(value)
                    .map(|(&ai, &x)| (ai - 1.0) * x.ln())
                    .sum::<f64>()
                    - norm
            }
        }
    }

    pub fn mean(&self) -> Option<f64> {
        use Dist::*;
        match self {
            Normal { loc, .. } => Some(*loc),
            Exponential { rate } => Some(1.0 / rate),
            Gamma {
                concentration,
                rate,
            } => Some(concentration / rate),
            Beta { a, b } => Some(a / (a + b)),
            Uniform { low, high } => Some(0.5 * (low + high)),
            BernoulliLogits { logits } => Some(sigmoid(*logits)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_density_peak() {
        let d = Dist::Normal {
            loc: 1.0,
            scale: 2.0,
        };
        // N(1, 2) at x=1: -log(2) - 0.5 log(2π)
        let expect = -(2f64.ln()) - 0.5 * LN_2PI;
        assert!((d.log_prob(&[1.0]) - expect).abs() < 1e-12);
    }

    #[test]
    fn densities_integrate_to_one() {
        // trapezoid integration over a wide grid
        let cases: Vec<(Dist, f64, f64)> = vec![
            (
                Dist::Normal {
                    loc: 0.5,
                    scale: 1.3,
                },
                -12.0,
                13.0,
            ),
            (Dist::HalfNormal { scale: 0.7 }, 1e-9, 10.0),
            (Dist::Exponential { rate: 2.0 }, 1e-9, 20.0),
            (
                Dist::Gamma {
                    concentration: 3.0,
                    rate: 2.0,
                },
                1e-9,
                30.0,
            ),
            (
                Dist::InverseGamma {
                    concentration: 3.0,
                    rate: 1.0,
                },
                1e-6,
                60.0,
            ),
            (Dist::Beta { a: 2.5, b: 1.5 }, 1e-9, 1.0 - 1e-9),
            (
                Dist::LogNormal {
                    loc: 0.0,
                    scale: 0.5,
                },
                1e-9,
                30.0,
            ),
        ];
        for (d, lo, hi) in cases {
            let n = 400_000;
            let h = (hi - lo) / n as f64;
            let mut total = 0.0;
            for i in 0..=n {
                let x = lo + i as f64 * h;
                let w = if i == 0 || i == n { 0.5 } else { 1.0 };
                total += w * d.log_prob(&[x]).exp();
            }
            total *= h;
            assert!((total - 1.0).abs() < 1e-3, "{d:?}: integral {total}");
        }
    }

    #[test]
    fn sampler_matches_density_moments() {
        let mut rng = Rng::new(42);
        let d = Dist::Gamma {
            concentration: 4.0,
            rate: 2.0,
        };
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng)[0]).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn bernoulli_logit_density() {
        let d = Dist::BernoulliLogits { logits: 0.7 };
        let p = sigmoid(0.7);
        assert!((d.log_prob(&[1.0]) - p.ln()).abs() < 1e-12);
        assert!((d.log_prob(&[0.0]) - (1.0 - p).ln()).abs() < 1e-12);
    }

    #[test]
    fn dirichlet_uniform_case() {
        // Dir(1,1,1) log-density = log Γ(3) = log 2 everywhere on the simplex
        let d = Dist::Dirichlet {
            concentration: vec![1.0, 1.0, 1.0],
        };
        assert!((d.log_prob(&[0.2, 0.3, 0.5]) - 2f64.ln()).abs() < 1e-10);
    }
}
