//! Special functions (no `libm`/`statrs` offline): Lanczos log-gamma,
//! log-beta, stable log-sum-exp / softplus helpers.

/// Lanczos approximation (g = 7, n = 9), |error| < 1e-13 over the real
/// positives; reflected for x < 0.5.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let s = (std::f64::consts::PI * x).sin();
        return std::f64::consts::PI.ln() - s.abs().ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    for (i, c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    let t = x + G + 0.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

pub fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

/// Overflow-safe log(1 + e^x).
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Softplus and sigmoid from a *single* shared `exp` — the fused-GLM
/// trick used by both the hand-coded logistic potential and the model
/// compiler's Bernoulli fast paths (keep them on this one
/// implementation so the golden cross-check stays bitwise-meaningful):
///
///   x >= 0: e = exp(-x), softplus = x + ln1p(e), sigmoid = 1/(1+e)
///   x <  0: e = exp(x),  softplus = ln1p(e),     sigmoid = e/(1+e)
#[inline(always)]
pub fn softplus_sigmoid(x: f64) -> (f64, f64) {
    if x >= 0.0 {
        let e = (-x).exp();
        (x + e.ln_1p(), 1.0 / (1.0 + e))
    } else {
        let e = x.exp();
        (e.ln_1p(), e / (1.0 + e))
    }
}

pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

pub fn logit(p: f64) -> f64 {
    (p / (1.0 - p)).ln()
}

pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

pub const LN_2PI: f64 = 1.8378770664093453;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_integer_factorials() {
        // Γ(n) = (n-1)!
        let facts: [f64; 7] = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (i, f) in facts.iter().enumerate() {
            let lg = ln_gamma((i + 1) as f64);
            assert!((lg - f.ln()).abs() < 1e-10, "n={} {} vs {}", i + 1, lg, f.ln());
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Γ(1/2) = sqrt(π)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn ln_gamma_recurrence() {
        // Γ(x+1) = x Γ(x)
        for &x in &[0.1, 0.7, 1.3, 4.6, 11.2] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = x.ln() + ln_gamma(x);
            assert!((lhs - rhs).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn softplus_stable() {
        assert_eq!(softplus(1000.0), 1000.0);
        assert!(softplus(-1000.0).abs() < 1e-300);
        assert!((softplus(0.0) - 2f64.ln()).abs() < 1e-15);
    }

    #[test]
    fn lse_matches_naive() {
        let xs: [f64; 3] = [0.3, -1.2, 2.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-12);
    }
}
