//! Constraint transforms (unconstrained <-> support) with log-Jacobians,
//! in two flavours: plain `f64` (diagnostics, initialization) and
//! [`Tape`]-valued (inside native potentials, so the Jacobian term is
//! differentiated along with the density).
//!
//! Matches `python/compile/minippl/transforms.py` exactly — including
//! the stick-breaking offsets — so unconstrained vectors are
//! interchangeable between the native and PJRT pipelines.

use crate::autodiff::{Tape, Var};
use crate::ppl::dist::Support;
use crate::ppl::special::{logit, sigmoid};

/// y = exp(x): R -> (0, inf). Returns (y, log|J|).
pub fn exp_transform(x: f64) -> (f64, f64) {
    (x.exp(), x)
}

pub fn exp_inverse(y: f64) -> f64 {
    y.ln()
}

/// y = sigmoid(x): R -> (0,1). Returns (y, log|J|).
pub fn sigmoid_transform(x: f64) -> (f64, f64) {
    let y = sigmoid(x);
    let ladj = -crate::ppl::special::softplus(x) - crate::ppl::special::softplus(-x);
    (y, ladj)
}

pub fn sigmoid_inverse(y: f64) -> f64 {
    logit(y)
}

/// Stick-breaking: R^{K-1} -> K-simplex (offset so x=0 maps to uniform).
/// Returns (y, log|J|).
pub fn stick_breaking(x: &[f64]) -> (Vec<f64>, f64) {
    let km1 = x.len();
    let mut y = Vec::with_capacity(km1 + 1);
    let mut rem: f64 = 1.0;
    let mut ladj = 0.0;
    for (i, &xi) in x.iter().enumerate() {
        let offset = ((km1 - i) as f64).ln();
        let zs = xi - offset;
        let z = sigmoid(zs);
        ladj += -crate::ppl::special::softplus(zs) - crate::ppl::special::softplus(-zs) + rem.ln();
        y.push(z * rem);
        rem *= 1.0 - z;
    }
    y.push(rem);
    (y, ladj)
}

pub fn stick_breaking_inverse(y: &[f64]) -> Vec<f64> {
    let k = y.len();
    let mut x = Vec::with_capacity(k - 1);
    let mut rem = 1.0;
    for i in 0..k - 1 {
        let offset = ((k - 1 - i) as f64).ln();
        let z = (y[i] / rem).clamp(1e-12, 1.0 - 1e-12);
        x.push(logit(z) + offset);
        rem -= y[i];
    }
    x
}

// ---------------------------------------------------------------------------
// Tape-valued versions (for native potentials)
// ---------------------------------------------------------------------------

/// exp transform on tape: returns (y, ladj contribution).
pub fn exp_transform_t(t: &mut Tape, x: Var) -> (Var, Var) {
    (t.exp(x), x)
}

/// Stick-breaking on tape: maps K-1 vars to K simplex vars; returns
/// (simplex, ladj).
pub fn stick_breaking_t(t: &mut Tape, x: &[Var]) -> (Vec<Var>, Var) {
    let mut ys = Vec::with_capacity(x.len() + 1);
    let mut scratch = Vec::with_capacity(x.len());
    let ladj = stick_breaking_t_into(t, x, &mut ys, &mut scratch);
    (ys, ladj)
}

/// Allocation-free [`stick_breaking_t`]: appends the K simplex vars to
/// `ys` (not cleared — callers batch several rows into one buffer) and
/// uses `scratch` for the per-stick ladj terms.  Returns ladj.
pub fn stick_breaking_t_into(
    t: &mut Tape,
    x: &[Var],
    ys: &mut Vec<Var>,
    scratch: &mut Vec<Var>,
) -> Var {
    scratch.clear();
    let km1 = x.len();
    let one = t.constant(1.0);
    let mut rem = one;
    for (i, &xi) in x.iter().enumerate() {
        let offset = ((km1 - i) as f64).ln();
        let zs = t.offset(xi, -offset);
        let z = t.sigmoid(zs);
        // log z' = -softplus(zs) - softplus(-zs)
        let sp_pos = t.softplus(zs);
        let neg_zs = t.neg(zs);
        let sp_neg = t.softplus(neg_zs);
        let log_rem = t.ln(rem);
        let sp_sum = t.add(sp_pos, sp_neg);
        let term = t.sub(log_rem, sp_sum);
        scratch.push(term);
        let y = t.mul(z, rem);
        ys.push(y);
        let one_minus_z = t.sub(one, z);
        rem = t.mul(rem, one_minus_z);
    }
    ys.push(rem);
    t.sum(scratch)
}

/// Transform an unconstrained tape var onto `support`; returns
/// (constrained, ladj). Simplex handled by [`stick_breaking_t`].
pub fn constrain_t(t: &mut Tape, support: Support, x: Var) -> (Var, Var) {
    match support {
        Support::Real => (x, t.constant(0.0)),
        Support::Positive => exp_transform_t(t, x),
        Support::UnitInterval => {
            let y = t.sigmoid(x);
            let sp = t.softplus(x);
            let nx = t.neg(x);
            let sn = t.softplus(nx);
            let sum = t.add(sp, sn);
            (y, t.neg(sum))
        }
        Support::Simplex | Support::Discrete => {
            panic!("constrain_t: unsupported scalar support {support:?}")
        }
    }
}

/// Unconstrained dimension needed to represent `support` of event length n.
pub fn unconstrained_len(support: Support, event_len: usize) -> usize {
    match support {
        Support::Simplex => event_len - 1,
        Support::Discrete => 0,
        _ => event_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;

    #[test]
    fn stick_breaking_roundtrip() {
        let x = [0.3, -1.2, 0.7, 2.0];
        let (y, _) = stick_breaking(&x);
        assert!((y.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(y.iter().all(|&v| v > 0.0));
        let x2 = stick_breaking_inverse(&y);
        for (a, b) in x.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn stick_breaking_zero_maps_to_uniform() {
        let (y, _) = stick_breaking(&[0.0, 0.0, 0.0]);
        for v in y {
            assert!((v - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn tape_stick_breaking_matches_plain() {
        let x = [0.5, -0.3, 1.1];
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let (ys, ladj) = stick_breaking_t(&mut t, &vars);
        let (y_plain, ladj_plain) = stick_breaking(&x);
        for (yv, yp) in ys.iter().zip(&y_plain) {
            assert!((t.value(*yv) - yp).abs() < 1e-12);
        }
        assert!((t.value(ladj) - ladj_plain).abs() < 1e-12);
    }

    #[test]
    fn tape_ladj_gradient_matches_fd() {
        let x = [0.2, -0.8];
        let f = |xs: &[f64]| stick_breaking(xs).1;
        let fd = finite_diff(&x, f, 1e-6);
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let (_, ladj) = stick_breaking_t(&mut t, &vars);
        let adj = t.grad(ladj);
        for i in 0..x.len() {
            assert!(
                (adj[vars[i].0 as usize] - fd[i]).abs() < 1e-6,
                "{} vs {}",
                adj[vars[i].0 as usize],
                fd[i]
            );
        }
    }

    #[test]
    fn sigmoid_transform_jacobian() {
        let (y, ladj) = sigmoid_transform(0.7);
        // dy/dx = y(1-y)
        assert!((ladj.exp() - y * (1.0 - y)).abs() < 1e-12);
    }
}
