//! Native-Rust probabilistic-programming substrate: distributions with
//! densities + samplers ([`dist`]), algebra-generic distributions for
//! the model compiler ([`distv`]), constraint transforms
//! ([`transforms`]) and special functions ([`special`]).  Together with
//! [`crate::effects`] this is the Rust-side mirror of the Python
//! `minippl` package.

pub mod dist;
pub mod distv;
pub mod special;
pub mod transforms;

pub use dist::{Dist, Support};
pub use distv::DistV;
