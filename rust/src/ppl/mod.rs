//! Native-Rust probabilistic-programming substrate: distributions with
//! densities + samplers ([`dist`]), constraint transforms ([`transforms`])
//! and special functions ([`special`]).  Together with [`crate::effects`]
//! this is the Rust-side mirror of the Python `minippl` package.

pub mod dist;
pub mod special;
pub mod transforms;

pub use dist::{Dist, Support};
