//! `fugue` — CLI for the NumPyro-paper reproduction stack.
//!
//! Subcommands:
//!   info                         list artifacts from the manifest
//!   run                          run NUTS on a model, print a summary
//!   experiment <name>            regenerate a paper table/figure
//!   artifacts-check              load + compile + smoke-run every artifact
//!   help
//!
//! Common flags: --artifacts DIR --results DIR --seed N --quick --full
//!               --warmup N --samples N --chains N --model NAME
//!               --backend fused|stepwise|native --dtype f32|f64

use anyhow::{bail, Context, Result};

use fugue::cli::Args;
use fugue::config::Settings;
use fugue::coordinator::{run_chains, NutsOptions};
use fugue::diagnostics::summary::{render_table, summarize};
use fugue::harness::{self, builders};
use fugue::runtime::engine::Engine;

const HELP: &str = "\
fugue — composable effects + end-to-end-compiled iterative NUTS (paper reproduction)

USAGE: fugue <subcommand> [flags]

SUBCOMMANDS
  bench                     native NUTS perf baseline: ms/leapfrog (optimized vs
                            seed baseline), parallel multi-chain scaling, and the
                            sequential-vs-parallel-vs-vectorized chain-engine
                            comparison (vectorized_speedup_vs_parallel per chain
                            count); writes machine-readable BENCH_native.json
                            (--out FILE, --chains K for the max chain count,
                            --quick).  Needs no artifacts and no pjrt feature.
  info                      list models/artifacts in the manifest
  run                       sample a model and print posterior summary
                            (--model NAME --backend fused|stepwise|native
                             --dtype f32|f64 --warmup N --samples N --chains N)
  sample-model              compile an effect-handler model (no hand-written
                            gradient) and sample it with native iterative NUTS:
                            --model eight-schools|horseshoe|logistic|funnel
                            (--chains K --warmup N --samples N --out FILE
                             --chain-method sequential|parallel|vectorized;
                             all three produce bitwise-identical chains —
                             vectorized runs them lock-step over a fused
                             multi-lane potential).
                            Fault containment: --checkpoint FILE saves a
                            resumable draw-boundary snapshot (--checkpoint-every
                            N draws, default 200); --resume continues a saved
                            run bitwise-identically; --max-seconds S stops at
                            the budget with partial results + checkpoint.
                            Needs no artifacts and no pjrt feature.
  svi-model                 fit a compiled effect-handler model with the native
                            SVI engine (reparameterized ADVI, mean-field normal
                            guide, frozen-tape gradients):
                            --model eight-schools|horseshoe|logistic
                            (--steps N --particles K --lr X --optimizer adam|sgd
                             --predictive N --out FILE; K particles run as one
                             fused multi-lane gradient sweep per step).
                            Same fault-containment flags as sample-model
                            (--checkpoint/--resume/--checkpoint-every/
                             --max-seconds); non-finite ELBO steps are skipped
                            with learning-rate backoff, never propagated.
                            Subsampling (--model logistic only): --subsample-size
                            B fits minibatches of B rows per ELBO step with the
                            N/B likelihood scale correction (Pyro plate
                            semantics; B = N is bitwise-identical to the
                            full-batch path).  --rows N [--dim D] swaps the
                            in-memory dataset for a streaming synthetic
                            logistic dataset of N rows generated on the fly —
                            memory stays O(B*D) even at N = 10,000,000.
                            Needs no artifacts and no pjrt feature.
  experiment table2a        Table 2a: ms/leapfrog across architectures (--model hmm|covtype)
  experiment fig2b          Fig 2b: SKIM ms/effective-sample vs p
  experiment footnote6      footnote 6: HMM ESS across seeds, f32 vs f64
  experiment fig1           Fig 1/App. B: vectorized prediction + log-lik
  experiment appendix-d     App. D: SVI with vectorized ELBO
  experiment ablate-vmap    E7: vmapped chains vs sequential dispatch
  experiment ablate-tree    E8: iterative vs recursive tree (native)
  experiment ablate-kernel  interpret-mode Pallas vs XLA-fused reference
  experiment all            everything above
  artifacts-check           compile + smoke-run every artifact in the manifest
  diagnose FILE.npy         ESS/R-hat summary of a saved posterior (--chains K)

FLAGS
  --artifacts DIR   artifact directory (default: artifacts)
  --results DIR     report directory (default: results)
  --seed N          base RNG seed
  --quick           ~10x smaller workloads (CI/smoke)
  --full            paper-scale workloads

OBSERVABILITY (bench, sample-model, svi-model, diagnose)
  --trace-out FILE      structured JSONL event stream (run_start, phase
                        changes, checkpoints, epochs, run_end)
  --metrics-out FILE    metrics snapshot (counters, gauges, tree-depth
                        histogram, timing spans, trajectory windows;
                        written atomically, schema fugue-metrics/v1)
  --metrics-every S     re-write the snapshot every S seconds while the
                        run is live (default snapshot path:
                        fugue-metrics.json)
  --progress            single-line live progress report on stderr
  Recording is bitwise-neutral: a run with these flags produces
  identical draws/ELBOs to one without (rust/tests/observability.rs).

The default build stubs out the PJRT runtime; `bench` and `diagnose`
work everywhere, the artifact-backed subcommands need `--features pjrt`
plus `make artifacts`.
";

fn cmd_info(engine: &Engine) -> Result<()> {
    println!("artifacts dir: {}", engine.manifest.dir.display());
    println!("models: {}", engine.manifest.models().join(", "));
    println!();
    println!(
        "{:<38} {:>6} {:>6} {:>22}",
        "artifact", "dim", "dtype", "kind"
    );
    for e in engine.manifest.entries.values() {
        println!(
            "{:<38} {:>6} {:>6} {:>22}",
            e.name, e.dim, e.dtype, e.kind
        );
    }
    Ok(())
}

/// Shared [`NutsOptions`] assembly for the sampling subcommands
/// (`run`, `sample-model`): a fixed `--step-size` disables both
/// step-size and mass adaptation.
fn nuts_options(
    args: &Args,
    settings: &Settings,
    warmup: usize,
    samples: usize,
) -> Result<NutsOptions> {
    let fixed = args.get_f64("step-size")?;
    Ok(NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        target_accept: settings.target_accept,
        adapt_mass: fixed.is_none(),
        fixed_step_size: fixed,
        init_step_size: 0.1,
        seed: settings.seed,
    })
}

fn cmd_run(engine: &Engine, args: &Args, settings: &Settings) -> Result<()> {
    let model = args.get("model").unwrap_or("covtype_small");
    let backend = builders::Backend::parse(args.get("backend").unwrap_or("fused"))?;
    let dtype = args.get("dtype").unwrap_or("f32");
    let (warmup, samples) = settings.budget(500, 500);

    println!(
        "model={model} backend={backend:?} dtype={dtype} warmup={warmup} samples={samples} chains={}",
        settings.num_chains
    );
    let workload = builders::Workload::for_model(engine, model, settings.seed)?;
    let mut sampler: Box<dyn fugue::coordinator::Sampler> =
        if let Some(steps) = args.get_usize("hmc-steps")? {
            // plain HMC (static trajectory) over the native potential —
            // the sampler NUTS exists to replace (mcmc/hmc.rs)
            anyhow::ensure!(
                backend == builders::Backend::Native,
                "--hmc-steps requires --backend native"
            );
            struct BoxedPotential(Box<dyn fugue::mcmc::Potential>);
            impl fugue::mcmc::Potential for BoxedPotential {
                fn dim(&self) -> usize {
                    self.0.dim()
                }
                fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
                    self.0.value_and_grad(z, grad)
                }
            }
            Box::new(fugue::mcmc::hmc::HmcSampler::new(
                BoxedPotential(workload.native_potential()?),
                steps as u32,
            ))
        } else {
            builders::build_sampler(
                engine,
                model,
                backend,
                dtype,
                &workload,
                settings.max_tree_depth,
            )?
        };
    let dim = sampler.dim();
    let opts = nuts_options(args, settings, warmup, samples)?;
    let t0 = std::time::Instant::now();
    let results = run_chains(&mut sampler, settings.num_chains, &opts)?;
    let total = t0.elapsed().as_secs_f64();

    let layout = engine
        .manifest
        .find(model, "nuts_step", dtype)
        .map(|e| e.param_layout.clone())
        .unwrap_or_default();
    let chains: Vec<Vec<f64>> = results.iter().map(|r| r.samples.clone()).collect();
    let rows = summarize(&chains, dim, &layout);
    println!("{}", render_table(&rows));

    if let Some(out) = args.get("out") {
        let all: Vec<f64> = chains.concat();
        let draws = all.len() / dim;
        fugue::util::npy::write_f64(out, &all, &[draws, dim])?;
        println!("posterior saved to {out} ({draws} x {dim}, numpy .npy)");
    }

    let leapfrogs: u64 = results.iter().map(|r| r.sample_leapfrogs).sum();
    let sample_secs: f64 = results.iter().map(|r| r.sample_secs).sum();
    let divergences: u64 = results.iter().map(|r| r.divergences).sum();
    println!(
        "total {total:.2}s | sampling {sample_secs:.2}s | {leapfrogs} leapfrogs | {:.4} ms/leapfrog | {} divergences | step sizes: {}",
        1e3 * sample_secs / leapfrogs.max(1) as f64,
        divergences,
        results
            .iter()
            .map(|r| format!("{:.4}", r.step_size))
            .collect::<Vec<_>>()
            .join(",")
    );
    Ok(())
}

fn cmd_artifacts_check(engine: &Engine, settings: &Settings) -> Result<()> {
    let names: Vec<String> = engine.manifest.entries.keys().cloned().collect();
    let mut failures = 0;
    for name in &names {
        let t0 = std::time::Instant::now();
        match check_one(engine, name, settings) {
            Ok(msg) => println!(
                "OK   {name:<42} {:>7.2}s  {msg}",
                t0.elapsed().as_secs_f64()
            ),
            Err(e) => {
                failures += 1;
                println!("FAIL {name:<42} {e:#}");
            }
        }
    }
    if failures > 0 {
        bail!("{failures}/{} artifacts failed", names.len());
    }
    println!("all {} artifacts OK", names.len());
    Ok(())
}

fn check_one(engine: &Engine, name: &str, settings: &Settings) -> Result<String> {
    let exe = engine.executable(name)?;
    let entry = exe.entry.clone();
    match entry.kind.as_str() {
        "nuts_step" | "nuts_step_vmap" => {
            let workload = builders::Workload::for_model(engine, &entry.model, settings.seed)?;
            let dt = entry.inputs[1].dtype;
            let mut step = fugue::runtime::NutsStep::new(engine, name, &workload.tensors(dt)?)?;
            let dim = entry.dim;
            if entry.kind == "nuts_step_vmap" {
                let k = entry.meta_usize("chains").unwrap_or(4);
                let trs = step.step_vmap(
                    &vec![[1u32, 2u32]; k],
                    &vec![0.1; k * dim],
                    &vec![0.01; k],
                    &vec![1.0; k * dim],
                )?;
                let lf: u32 = trs.iter().map(|t| t.num_leapfrog).sum();
                Ok(format!("{k} chains, {lf} leapfrogs"))
            } else {
                let tr = step.step([1, 2], &vec![0.1; dim], 0.01, &vec![1.0; dim])?;
                anyhow::ensure!(tr.num_leapfrog > 0, "no leapfrogs taken");
                anyhow::ensure!(tr.potential.is_finite(), "non-finite potential");
                Ok(format!(
                    "{} leapfrogs, U={:.2}",
                    tr.num_leapfrog, tr.potential
                ))
            }
        }
        "potential_and_grad" => {
            let workload = builders::Workload::for_model(engine, &entry.model, settings.seed)?;
            let dt = entry.inputs[0].dtype;
            let mut pot =
                fugue::runtime::PjrtPotential::new(engine, name, &workload.tensors(dt)?)?;
            let dim = entry.dim;
            let mut grad = vec![0.0; dim];
            let u = pot.eval(&vec![0.1; dim], &mut grad)?;
            anyhow::ensure!(u.is_finite(), "non-finite potential");
            anyhow::ensure!(grad.iter().all(|g| g.is_finite()), "non-finite grad");
            Ok(format!(
                "U={u:.2} |g|={:.2}",
                grad.iter().map(|g| g * g).sum::<f64>().sqrt()
            ))
        }
        _ => {
            // predict / loglik / elbo artifacts: compile-only check here;
            // exercised end-to-end by `experiment fig1` / `appendix-d`.
            Ok(format!("compiled ({} inputs)", entry.inputs.len()))
        }
    }
}

fn cmd_experiment(engine: &Engine, args: &Args, settings: &Settings) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .context("experiment name required (table2a|fig2b|footnote6|fig1|appendix-d|ablate-vmap|ablate-tree|all)")?;
    let model_filter = args.get("model");
    let run_one = |name: &str| -> Result<()> {
        let report = match name {
            "table2a" => harness::table2a::run(engine, settings, model_filter)?,
            "fig2b" => harness::fig2b::run(engine, settings)?,
            "footnote6" => harness::footnote6::run(engine, settings)?,
            "fig1" => harness::fig1::run(engine, settings)?,
            "appendix-d" => harness::appendix_d::run(engine, settings)?,
            "ablate-vmap" => harness::ablations::ablate_vmap(engine, settings)?,
            "ablate-tree" => harness::ablations::ablate_tree(engine, settings)?,
            "ablate-kernel" => harness::ablations::ablate_kernel(engine, settings)?,
            other => bail!("unknown experiment '{other}'"),
        };
        harness::emit(settings, name, &report)
    };
    if which == "all" {
        for name in [
            "table2a",
            "fig2b",
            "footnote6",
            "fig1",
            "appendix-d",
            "ablate-vmap",
            "ablate-tree",
            "ablate-kernel",
        ] {
            println!("\n================ {name} ================\n");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if args.positional.is_empty() || args.positional[0] == "help" {
        print!("{HELP}");
        return Ok(());
    }
    let settings = Settings::from_args(&args)?;
    let sub = args.subcommand()?;
    // `bench`, `sample-model`, `svi-model` and `diagnose` are
    // native-only: no artifact manifest, no PJRT engine — they must
    // work on a fresh clone with the default (stub) feature set.
    match sub {
        "bench" | "sample-model" | "svi-model" | "diagnose" => {
            // flight recorder: installed only when an observability
            // flag asks for it; recording is bitwise-neutral, so the
            // subcommands below never need to know it is on
            let obs = ObsSession::from_args(&args, sub)?;
            let result = match sub {
                "bench" => cmd_bench(&args, &settings),
                "sample-model" => cmd_sample_model(&args, &settings),
                "svi-model" => cmd_svi_model(&args, &settings),
                _ => cmd_diagnose(&args, &settings),
            };
            if let Some(o) = obs {
                o.finish()?;
            }
            return result;
        }
        _ => {}
    }
    let engine = Engine::new(&settings.artifacts_dir)?;
    match sub {
        "info" => cmd_info(&engine),
        "run" => cmd_run(&engine, &args, &settings),
        "experiment" => cmd_experiment(&engine, &args, &settings),
        "artifacts-check" => cmd_artifacts_check(&engine, &settings),
        other => bail!("unknown subcommand '{other}'; run `fugue help`"),
    }
}

/// One CLI run's flight-recorder session (`--trace-out`,
/// `--metrics-out`, `--metrics-every`, `--progress`): installs the
/// process-global registry, runs the exporter thread off the hot path,
/// and finalizes the trace stream + final snapshot on exit.
struct ObsSession {
    reg: &'static fugue::obs::MetricsRegistry,
    trace: Option<std::sync::Arc<fugue::obs::TraceWriter>>,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    exporter: Option<std::thread::JoinHandle<()>>,
    metrics_out: Option<std::path::PathBuf>,
    progress: bool,
}

impl ObsSession {
    /// `Some` only when at least one observability flag was passed —
    /// otherwise the global recorder stays uninstalled and every
    /// engine runs with recording disabled (one branch per site).
    fn from_args(args: &Args, sub: &str) -> Result<Option<ObsSession>> {
        use fugue::obs::{TraceWriter, Val};
        use std::sync::{atomic::AtomicBool, Arc};

        let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
        let metrics_every = args.get_f64("metrics-every")?;
        let metrics_out = args
            .get("metrics-out")
            .map(std::path::PathBuf::from)
            .or_else(|| metrics_every.map(|_| std::path::PathBuf::from("fugue-metrics.json")));
        let progress = args.has("progress");
        if trace_out.is_none() && metrics_out.is_none() && !progress {
            return Ok(None);
        }
        let rec = fugue::obs::install();
        let reg = rec.registry().expect("freshly installed recorder");
        let trace = match &trace_out {
            Some(p) => {
                let t = TraceWriter::create(p)?;
                t.event("run_start", &[("subcommand", Val::S(sub.to_string()))])?;
                Some(Arc::new(t))
            }
            None => None,
        };
        let stop = Arc::new(AtomicBool::new(false));
        let exporter = {
            let stop = stop.clone();
            let trace = trace.clone();
            let metrics_out = metrics_out.clone();
            let every = metrics_every
                .map(|s| std::time::Duration::from_secs_f64(s.max(0.05)));
            Some(std::thread::spawn(move || {
                exporter_loop(reg, &stop, trace.as_deref(), metrics_out.as_deref(), every, progress)
            }))
        };
        Ok(Some(ObsSession {
            reg,
            trace,
            stop,
            exporter,
            metrics_out,
            progress,
        }))
    }

    /// Stop the exporter, write the final snapshot and `run_end`
    /// event, and disable the global recorder.
    fn finish(mut self) -> Result<()> {
        use fugue::obs::{Counter, Val};
        self.stop.store(true, std::sync::atomic::Ordering::Release);
        if let Some(t) = self.exporter.take() {
            let _ = t.join();
        }
        if self.progress {
            eprintln!(); // terminate the \r-overwritten progress line
        }
        if let Some(p) = &self.metrics_out {
            fugue::obs::write_snapshot(self.reg, p)?;
            println!("metrics snapshot saved to {}", p.display());
        }
        if let Some(t) = &self.trace {
            t.event(
                "run_end",
                &[
                    ("uptime_ms", Val::F(self.reg.uptime().as_secs_f64() * 1e3)),
                    ("phase", Val::S(self.reg.phase().name().to_string())),
                    ("draws", Val::U(self.reg.counter(Counter::Draws))),
                    ("leapfrogs", Val::U(self.reg.counter(Counter::Leapfrogs))),
                    ("divergences", Val::U(self.reg.counter(Counter::Divergences))),
                    ("svi_steps", Val::U(self.reg.counter(Counter::SviSteps))),
                ],
            )?;
            println!("trace stream saved to {}", t.path().display());
        }
        fugue::obs::uninstall();
        Ok(())
    }
}

/// Exporter thread body: polls the all-atomic registry (never the hot
/// path), deriving trace events from phase/counter deltas, re-writing
/// the periodic snapshot, and repainting the progress line.
fn exporter_loop(
    reg: &'static fugue::obs::MetricsRegistry,
    stop: &std::sync::atomic::AtomicBool,
    trace: Option<&fugue::obs::TraceWriter>,
    metrics_out: Option<&std::path::Path>,
    snapshot_every: Option<std::time::Duration>,
    progress: bool,
) {
    use fugue::obs::{Counter, Val};
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    let mut last_phase = reg.phase();
    let mut last_ckpt = reg.counter(Counter::CheckpointWrites);
    let mut last_epoch = reg.counter(Counter::Epochs);
    let mut last_snapshot = Instant::now();
    let mut last_progress = Instant::now();
    while !stop.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(50));
        if let Some(t) = trace {
            let phase = reg.phase();
            if phase != last_phase {
                let _ = t.event("phase", &[("phase", Val::S(phase.name().to_string()))]);
                last_phase = phase;
            }
            let ckpt = reg.counter(Counter::CheckpointWrites);
            if ckpt != last_ckpt {
                let _ = t.event("checkpoint", &[("writes", Val::U(ckpt))]);
                last_ckpt = ckpt;
            }
            let ep = reg.counter(Counter::Epochs);
            if ep != last_epoch {
                let _ = t.event(
                    "epoch",
                    &[
                        ("epochs", Val::U(ep)),
                        ("rows_streamed", Val::U(reg.counter(Counter::RowsStreamed))),
                    ],
                );
                last_epoch = ep;
            }
        }
        if let (Some(every), Some(path)) = (snapshot_every, metrics_out) {
            if last_snapshot.elapsed() >= every {
                let _ = fugue::obs::write_snapshot(reg, path);
                last_snapshot = Instant::now();
            }
        }
        if progress && last_progress.elapsed() >= Duration::from_secs(1) {
            eprint!("\r{}", fugue::obs::progress_line(reg));
            let _ = std::io::Write::flush(&mut std::io::stderr());
            last_progress = Instant::now();
        }
    }
}

/// `fugue bench [--chains K] [--out FILE] [--quick]` — time the native
/// hot path and the parallel chain runner; emit BENCH_native.json.
fn cmd_bench(args: &Args, settings: &Settings) -> Result<()> {
    // honor an explicit --chains exactly; default to a 4-chain sweep
    let max_chains = match args.get_usize("chains")? {
        Some(k) => k.max(1),
        None => 4,
    };
    let out = args.get("out").unwrap_or("BENCH_native.json");
    let report = fugue::harness::bench_native::run(settings, max_chains, out)?;
    print!("{report}");
    Ok(())
}

/// `fugue sample-model --model NAME` — compile an effect-handler
/// program (pure sample/observe, no hand-written gradient) and sample
/// it end-to-end with the native iterative NUTS engine across parallel
/// chains.  Draws are reported in the *constrained* space.
fn cmd_sample_model(args: &Args, settings: &Settings) -> Result<()> {
    use fugue::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NealsFunnel};
    use fugue::compile::{EffModel, SiteLayout};
    use fugue::coordinator::{
        run_compiled_chains_checkpointed, run_compiled_chains_method, ChainMethod,
        ChainResult, CheckpointConfig,
    };

    let name = args.get("model").unwrap_or("eight-schools");
    let method = ChainMethod::parse(args.get("chain-method").unwrap_or("parallel"))?;
    let (warmup, samples) = settings.budget(1000, 1000);
    let chains = settings.num_chains;
    let opts = nuts_options(args, settings, warmup, samples)?;
    let ckpt = CheckpointConfig {
        path: args.get("checkpoint").map(std::path::PathBuf::from),
        resume: args.has("resume"),
        every: args.get_usize("checkpoint-every")?.unwrap_or(200).max(1),
        max_seconds: args.get_f64("max-seconds")?,
    };
    // the containment-aware runner only when its features are requested
    // — the plain path keeps e.g. true thread-parallel chains
    let contained = ckpt.path.is_some() || ckpt.max_seconds.is_some();
    println!(
        "compiled model={name} warmup={warmup} samples={samples} chains={chains} method={} seed={}",
        method.name(),
        settings.seed
    );

    fn dispatch<M: EffModel + Clone + Send + Sync>(
        model: &M,
        method: ChainMethod,
        chains: usize,
        depth: u32,
        opts: &fugue::coordinator::NutsOptions,
        ckpt: &CheckpointConfig,
        contained: bool,
    ) -> Result<(SiteLayout, Vec<ChainResult>, bool)> {
        if contained {
            run_compiled_chains_checkpointed(model, method, chains, depth, opts, ckpt)
        } else {
            let (layout, results) =
                run_compiled_chains_method(model, method, chains, depth, opts)?;
            Ok((layout, results, true))
        }
    }

    let t0 = std::time::Instant::now();
    let (layout, results, completed) = match name {
        "eight-schools" => dispatch(
            &EightSchools::classic(),
            method,
            chains,
            settings.max_tree_depth,
            &opts,
            &ckpt,
            contained,
        )?,
        "horseshoe" => {
            let model = Horseshoe::synthetic(settings.seed, 100, 10, 3);
            dispatch(&model, method, chains, settings.max_tree_depth, &opts, &ckpt, contained)?
        }
        "logistic" => {
            let (n, d) = (500, 8);
            let dset = fugue::data::make_covtype_like(settings.seed, n, d);
            let model = LogisticModel {
                x: dset.x,
                y: dset.y,
                n,
                d,
            };
            dispatch(&model, method, chains, settings.max_tree_depth, &opts, &ckpt, contained)?
        }
        "funnel" => dispatch(
            &NealsFunnel::classic(),
            method,
            chains,
            settings.max_tree_depth,
            &opts,
            &ckpt,
            contained,
        )?,
        other => {
            bail!("unknown compiled model '{other}' (eight-schools|horseshoe|logistic|funnel)")
        }
    };
    let total = t0.elapsed().as_secs_f64();

    // report draws in the constrained space, labeled by site
    let dim = layout.dim;
    let constrained: Vec<Vec<f64>> = results
        .iter()
        .map(|r| {
            let mut draws = r.samples.clone();
            for row in draws.chunks_mut(dim) {
                layout.constrain_row(row);
            }
            draws
        })
        .collect();
    let spans = layout.param_spans();
    let rows = summarize(&constrained, dim, &spans);
    println!("{}", render_table(&rows));

    if let Some(out) = args.get("out") {
        let all: Vec<f64> = constrained.concat();
        let draws = all.len() / dim;
        fugue::util::npy::write_f64(out, &all, &[draws, dim])?;
        println!("constrained posterior saved to {out} ({draws} x {dim}, numpy .npy)");
    }

    let leapfrogs: u64 = results.iter().map(|r| r.sample_leapfrogs).sum();
    let sample_secs: f64 = results.iter().map(|r| r.sample_secs).sum();
    let divergences: u64 = results.iter().map(|r| r.divergences).sum();
    let quarantines: u64 = results.iter().map(|r| r.quarantines).sum();
    println!(
        "total {total:.2}s | {leapfrogs} leapfrogs | {:.4} ms/leapfrog | {} divergences | {} quarantined draws | step sizes: {}",
        1e3 * sample_secs / leapfrogs.max(1) as f64,
        divergences,
        quarantines,
        results
            .iter()
            .map(|r| format!("{:.4}", r.step_size))
            .collect::<Vec<_>>()
            .join(",")
    );
    if !completed {
        let done: usize = results.first().map(|r| r.samples.len() / dim.max(1)).unwrap_or(0);
        println!(
            "WARNING: {}",
            fugue::error::InferenceError::BudgetExhausted {
                budget_secs: ckpt.max_seconds.unwrap_or(0.0),
                completed: done,
                requested: opts.num_samples,
            }
        );
        if let Some(p) = &ckpt.path {
            println!("resume with: fugue sample-model --checkpoint {} --resume ...", p.display());
        }
    }
    Ok(())
}

/// `fugue svi-model --model NAME` — compile an effect-handler program
/// and fit it with the native SVI engine: reparameterized ADVI with a
/// mean-field normal guide over the model's unconstrained layout, K
/// ELBO particles per step evaluated as one fused multi-lane sweep of
/// the frozen tape program.  Fully offline — no artifacts, no pjrt.
fn cmd_svi_model(args: &Args, settings: &Settings) -> Result<()> {
    use fugue::compile::zoo::{EightSchools, Horseshoe, LogisticModel};
    use fugue::coordinator::CheckpointConfig;
    use fugue::svi::{Convergence, OptimKind, StepSchedule, SviOptions};

    let name = args.get("model").unwrap_or("eight-schools");
    let steps = args
        .get_usize("steps")?
        .unwrap_or(if settings.quick { 300 } else { 2000 });
    let particles = args.get_usize("particles")?.unwrap_or(8).max(1);
    let lr = args.get_f64("lr")?.unwrap_or(0.05);
    let optimizer = OptimKind::parse(args.get("optimizer").unwrap_or("adam"))?;
    let opts = SviOptions {
        num_steps: steps,
        num_particles: particles,
        lr,
        seed: settings.seed,
        optimizer,
        // anneal to lr/10 over the run: converged guides stop wobbling
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.1,
            over: steps,
        },
        vectorize_particles: !args.has("no-vectorize-particles"),
        convergence: Some(Convergence {
            window: (steps / 10).max(25),
            rel_tol: 1e-5,
        }),
        tail_average: 0.25,
    };
    let ckpt = CheckpointConfig {
        path: args.get("checkpoint").map(std::path::PathBuf::from),
        resume: args.has("resume"),
        every: args.get_usize("checkpoint-every")?.unwrap_or(200).max(1),
        max_seconds: args.get_f64("max-seconds")?,
    };
    println!(
        "native SVI model={name} steps={steps} particles={particles} lr={lr} optimizer={} seed={}",
        optimizer.name(),
        settings.seed
    );
    let subsample = args.get_usize("subsample-size")?;
    if subsample.is_some() && name != "logistic" {
        bail!("--subsample-size is only supported for --model logistic");
    }
    match name {
        "eight-schools" => {
            svi_fit_and_report(&EightSchools::classic(), &opts, &ckpt, args, settings)
        }
        "horseshoe" => {
            let model = Horseshoe::synthetic(settings.seed, 100, 10, 3);
            svi_fit_and_report(&model, &opts, &ckpt, args, settings)
        }
        "logistic" => {
            use fugue::compile::SubsampledLogistic;
            use fugue::data::{InMemoryRows, RowLoader, SyntheticLogisticStream};
            // --rows switches to the streaming synthetic dataset: rows
            // are generated on demand, so the full matrix never exists
            if let Some(rows) = args.get_usize("rows")? {
                let d = args.get_usize("dim")?.unwrap_or(8);
                let batch = subsample
                    .context("--rows needs --subsample-size (streaming data is minibatch-only)")?;
                let loader = SyntheticLogisticStream::new(settings.seed, rows, d);
                println!(
                    "streaming synthetic logistic: {rows} rows x {d} dims, minibatch {batch} \
                     (resident: {} floats)",
                    batch * (d + 1)
                );
                let model = SubsampledLogistic::new(loader, batch);
                return svi_fit_and_report_subsampled(&model, &opts, &ckpt, args, settings);
            }
            let (n, d) = (500, 8);
            let dset = fugue::data::make_covtype_like(settings.seed, n, d);
            if let Some(batch) = subsample {
                let loader = InMemoryRows::new(dset.x, dset.y, n, d);
                println!(
                    "subsampled logistic: {n} rows, minibatch {batch} (scale {:.2})",
                    loader.num_rows() as f64 / batch as f64
                );
                let model = SubsampledLogistic::new(loader, batch);
                return svi_fit_and_report_subsampled(&model, &opts, &ckpt, args, settings);
            }
            let model = LogisticModel {
                x: dset.x,
                y: dset.y,
                n,
                d,
            };
            svi_fit_and_report(&model, &opts, &ckpt, args, settings)
        }
        other => bail!("unknown compiled model '{other}' (eight-schools|horseshoe|logistic)"),
    }
}

/// Shared fit/report body of `svi-model`, generic over the program.
fn svi_fit_and_report<M: fugue::compile::EffModel + Clone + Send>(
    model: &M,
    opts: &fugue::svi::SviOptions,
    ckpt: &fugue::coordinator::CheckpointConfig,
    args: &Args,
    settings: &Settings,
) -> Result<()> {
    use fugue::coordinator::{run_svi_checkpointed, run_svi_native};

    let contained = ckpt.path.is_some() || ckpt.max_seconds.is_some();
    let (layout, result) = if contained {
        run_svi_checkpointed(model, opts, ckpt)?
    } else {
        run_svi_native(model, opts)?
    };
    svi_report(model, &layout, &result, opts, ckpt, args, settings)
}

/// [`svi_fit_and_report`] for subsampled models: same reporting, but
/// the fit swaps minibatches into the frozen potential every step.
fn svi_fit_and_report_subsampled<M: fugue::compile::SubsampledModel + Clone + Send>(
    model: &M,
    opts: &fugue::svi::SviOptions,
    ckpt: &fugue::coordinator::CheckpointConfig,
    args: &Args,
    settings: &Settings,
) -> Result<()> {
    use fugue::coordinator::{run_svi_subsampled, run_svi_subsampled_checkpointed};

    let contained = ckpt.path.is_some() || ckpt.max_seconds.is_some();
    let (layout, result) = if contained {
        run_svi_subsampled_checkpointed(model, opts, ckpt)?
    } else {
        run_svi_subsampled(model, opts)?
    };
    svi_report(model, &layout, &result, opts, ckpt, args, settings)
}

fn svi_report<M: fugue::compile::EffModel + Clone>(
    model: &M,
    layout: &fugue::compile::SiteLayout,
    result: &fugue::svi::NativeSviResult,
    opts: &fugue::svi::SviOptions,
    ckpt: &fugue::coordinator::CheckpointConfig,
    args: &Args,
    settings: &Settings,
) -> Result<()> {
    use fugue::svi::posterior_predictive_draws;

    let chunk = (result.steps / 6).max(1);
    for (i, c) in result.elbo_trace.chunks(chunk).enumerate() {
        let mean = c.iter().sum::<f64>() / c.len() as f64;
        println!(
            "steps {:>5}-{:>5}: mean ELBO {:>14.4}",
            i * chunk,
            i * chunk + c.len(),
            mean
        );
    }
    println!(
        "{} steps in {:.2}s{}{}",
        result.steps,
        result.secs,
        if result.converged {
            " (converged early)"
        } else {
            ""
        },
        if result.skipped > 0 {
            format!(" | {} non-finite steps skipped (contained)", result.skipped)
        } else {
            String::new()
        }
    );
    // convergence diagnostic: the ELBO's Monte-Carlo noise floor over
    // the same window the early-stop rule compares means across
    if result.steps > 0 {
        let mcse_window = opts
            .convergence
            .map_or((result.steps / 10).max(25), |c| c.window)
            .min(result.steps);
        println!(
            "ELBO MC-SE {:.4} over the final {mcse_window}-step window (final ELBO {:.4})",
            result.elbo_mcse,
            result.final_elbo(mcse_window),
        );
    }
    if !result.completed {
        println!(
            "WARNING: {}",
            fugue::error::InferenceError::BudgetExhausted {
                budget_secs: ckpt.max_seconds.unwrap_or(0.0),
                completed: result.steps,
                requested: opts.num_steps,
            }
        );
        if let Some(p) = &ckpt.path {
            println!("resume with: fugue svi-model --checkpoint {} --resume ...", p.display());
        }
    }

    // posterior summary from the fitted guide, in the constrained space
    let dim = layout.dim;
    let mut rng = fugue::rng::Rng::new(settings.seed ^ 0x5A17);
    let draws = result.guide.posterior_draws(layout, &mut rng, 2000);
    let spans = layout.param_spans();
    let rows = summarize(std::slice::from_ref(&draws), dim, &spans);
    println!("{}", render_table(&rows));

    if let Some(n) = args.get_usize("predictive")? {
        let pred = posterior_predictive_draws(model, layout, &result.guide, settings.seed, n);
        println!("posterior predictive ({n} replicates per observation site):");
        for (i, (site, vals)) in pred.iter().enumerate() {
            if i == 8 {
                println!("  ... ({} more sites)", pred.len() - 8);
                break;
            }
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / vals.len() as f64;
            println!("  {site:<12} mean {m:>10.4}  sd {:>10.4}", v.sqrt());
        }
    }

    if let Some(out) = args.get("out") {
        let n_draws = draws.len() / dim;
        fugue::util::npy::write_f64(out, &draws, &[n_draws, dim])?;
        println!("constrained guide draws saved to {out} ({n_draws} x {dim}, numpy .npy)");
    }
    Ok(())
}

/// `fugue diagnose <posterior.npy> [--chains K]` — summaries + ESS/R-hat
/// for a saved posterior (splits rows evenly across K chains).
fn cmd_diagnose(args: &Args, settings: &Settings) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("diagnose requires a .npy path (from `fugue run --out ...`)")?;
    let (data, shape) = fugue::util::npy::read_f64(path)?;
    anyhow::ensure!(shape.len() == 2, "expected 2-d draws x dim array");
    let (draws, dim) = (shape[0], shape[1]);
    let k = settings.num_chains.max(1).min(draws);
    let per = draws / k;
    let chains: Vec<Vec<f64>> = (0..k)
        .map(|c| data[c * per * dim..(c + 1) * per * dim].to_vec())
        .collect();
    let rows = summarize(&chains, dim, &[]);
    println!("{}", render_table(&rows));
    println!(
        "{} draws x {} params as {} chain(s) | min ESS {:.0} | max split-Rhat {:.3}",
        draws,
        dim,
        k,
        fugue::diagnostics::summary::min_ess(&rows),
        rows.iter().map(|r| r.rhat).fold(0.0, f64::max)
    );
    Ok(())
}
